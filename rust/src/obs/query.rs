//! Forensic queries over a canonically sorted frame slice (ISSUE 10,
//! DESIGN.md §18) — the engine behind `rollmux trace <archive> <query>`.
//!
//! Every query is a pure function of `&[Frame]` in the recorder's
//! canonical order (callers sort with
//! [`crate::sim::recorder::canonical_sort_frames`] after loading an
//! archive), and every renderer walks its rows in that deterministic
//! order, so a serial producer, a `run_parallel` producer and a
//! daemon-appended archive all answer byte-identically.
//!
//! * [`slo_breach`] — ROADMAP item 4 verbatim: every group whose SLO
//!   slack went negative within a window before a crash.
//! * [`bubbles`] — per-group dependency-bubble attribution: of each
//!   job's train+sync seconds (its rollout pool idle), how much was
//!   reclaimed by another member's rollout vs left unreclaimed.
//! * [`explain`] — the full provenance chain for one job.
//! * [`util_series`] — one group's cumulative busy samples with deltas.
//! * [`histograms`] — fixed-boundary distributions of queue wait, phase
//!   durations and SLO slack.

use std::collections::BTreeMap;

use crate::metrics::histogram::Histogram;
use crate::sim::engine::{PhaseKind, WorldEvent};
use crate::sim::recorder::Frame;
use crate::util::json::{arr, num, obj, s, Json};
use crate::workload::job::JobId;

/// `Json::Num` that stays parseable: non-finite values (an infeasible
/// candidate's Δ-cost) serialize as `null`, as in `metrics::chaos_point_json`.
fn jnum(v: f64) -> Json {
    if v.is_finite() {
        num(v)
    } else {
        Json::Null
    }
}

fn fmt_cost(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "inf".to_string()
    }
}

/// `usize::MAX` sentinels (unknown group, cap-shrink pseudo-node) render
/// as `-` in tables.
fn fmt_id(v: usize) -> String {
    if v == usize::MAX {
        "-".to_string()
    } else {
        v.to_string()
    }
}

// ---------------------------------------------------------------- slo-breach

/// One breach sample attributed to one crash: job `job` (running in
/// group `gid` at the sample time) had negative slack `slack_s` at
/// `slack_t`, within the window before the crash at `crash_t`.
#[derive(Clone, Debug, PartialEq)]
pub struct SloBreachRow {
    pub crash_t: f64,
    pub crash_gid: usize,
    pub crash_node: usize,
    pub job: JobId,
    pub gid: usize,
    pub iter: usize,
    pub slack_t: f64,
    pub slack_s: f64,
}

/// ROADMAP item 4's query: for every crash, every SLO-slack sample that
/// went negative within `window_s` seconds at or before it, with the
/// breaching job mapped to the group it was running in at sample time
/// (its latest phase record at or before `slack_t`; `usize::MAX` if the
/// job had no phase yet). Rows are ordered by crash, then sample.
pub fn slo_breach(frames: &[Frame], window_s: f64) -> Vec<SloBreachRow> {
    // Job → (phase start, group) in ascending start order, for the
    // job-to-group mapping at an arbitrary time.
    let mut job_groups: BTreeMap<JobId, Vec<(f64, usize)>> = BTreeMap::new();
    for f in frames {
        if let Frame::Phase(r) = f {
            job_groups.entry(r.job).or_default().push((r.start, r.group));
        }
    }
    let breaches: Vec<(f64, JobId, usize, f64)> = frames
        .iter()
        .filter_map(|f| match f {
            Frame::SloSlack { t, job, iter, slack_s } if *slack_s < 0.0 => {
                Some((*t, *job, *iter, *slack_s))
            }
            _ => None,
        })
        .collect();
    let mut rows = Vec::new();
    for f in frames {
        if let Frame::World(WorldEvent::Crash { t, gid, node }) = *f {
            for &(slack_t, job, iter, slack_s) in &breaches {
                if slack_t < t - window_s || slack_t > t {
                    continue;
                }
                let group = job_groups
                    .get(&job)
                    .map(|v| {
                        let i = v.partition_point(|&(start, _)| start <= slack_t);
                        if i == 0 { usize::MAX } else { v[i - 1].1 }
                    })
                    .unwrap_or(usize::MAX);
                rows.push(SloBreachRow {
                    crash_t: t,
                    crash_gid: gid,
                    crash_node: node,
                    job,
                    gid: group,
                    iter,
                    slack_t,
                    slack_s,
                });
            }
        }
    }
    rows
}

pub fn slo_breach_table(rows: &[SloBreachRow], window_s: f64) -> String {
    let mut out = format!("slo-breach: window {window_s:.0}s, {} row(s)\n", rows.len());
    out.push_str(&format!(
        "{:>12} {:>6} {:>6} {:>6} {:>6} {:>6} {:>12} {:>12}\n",
        "crash_t", "c_gid", "node", "job", "gid", "iter", "slack_t", "slack_s"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:>12.3} {:>6} {:>6} {:>6} {:>6} {:>6} {:>12.3} {:>12.3}\n",
            r.crash_t,
            r.crash_gid,
            fmt_id(r.crash_node),
            r.job,
            fmt_id(r.gid),
            r.iter,
            r.slack_t,
            r.slack_s
        ));
    }
    out
}

pub fn slo_breach_jsonl(rows: &[SloBreachRow]) -> String {
    rows.iter()
        .map(|r| {
            obj(vec![
                ("crash_t", num(r.crash_t)),
                ("crash_gid", num(r.crash_gid as f64)),
                ("crash_node", jnum_id(r.crash_node)),
                ("job", num(r.job as f64)),
                ("gid", jnum_id(r.gid)),
                ("iter", num(r.iter as f64)),
                ("slack_t", num(r.slack_t)),
                ("slack_s", num(r.slack_s)),
            ])
            .to_string()
                + "\n"
        })
        .collect()
}

fn jnum_id(v: usize) -> Json {
    if v == usize::MAX {
        Json::Null
    } else {
        num(v as f64)
    }
}

// ------------------------------------------------------------------- bubbles

/// Per-group dependency-bubble attribution (the paper's structural-
/// idleness argument read off a recorded run): `bubble_s` is the total
/// train+sync seconds of the group's members (seconds their rollout
/// allocation sat in a dependency bubble), split into seconds overlapped
/// by at least one *other* member's rollout (`reclaimed_s`) and the
/// remainder (`unreclaimed_s`).
#[derive(Clone, Debug, PartialEq)]
pub struct BubbleRow {
    pub gid: usize,
    pub bubble_s: f64,
    pub reclaimed_s: f64,
    pub unreclaimed_s: f64,
}

pub fn bubbles(frames: &[Frame]) -> Vec<BubbleRow> {
    type ByJob = BTreeMap<JobId, Vec<(f64, f64)>>;
    let mut rolls: BTreeMap<usize, ByJob> = BTreeMap::new();
    let mut bubs: BTreeMap<usize, ByJob> = BTreeMap::new();
    for f in frames {
        if let Frame::Phase(r) = f {
            let slot = match r.kind {
                PhaseKind::Rollout => &mut rolls,
                PhaseKind::Train | PhaseKind::Sync => &mut bubs,
                PhaseKind::Init => continue,
            };
            slot.entry(r.group).or_default().entry(r.job).or_default().push((r.start, r.end));
        }
    }
    let mut rows = Vec::new();
    for (&gid, jobs) in &bubs {
        let mut bubble_s = 0.0;
        let mut reclaimed_s = 0.0;
        for (&job, iv) in jobs {
            bubble_s += iv.iter().map(|&(a, b)| b - a).sum::<f64>();
            let others: Vec<(f64, f64)> = rolls
                .get(&gid)
                .map(|m| {
                    m.iter()
                        .filter(|&(&j, _)| j != job)
                        .flat_map(|(_, v)| v.iter().copied())
                        .collect()
                })
                .unwrap_or_default();
            reclaimed_s += overlap_len(iv, &interval_union(others));
        }
        rows.push(BubbleRow { gid, bubble_s, reclaimed_s, unreclaimed_s: bubble_s - reclaimed_s });
    }
    rows
}

/// Merge intervals into a disjoint ascending union.
fn interval_union(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut out: Vec<(f64, f64)> = Vec::new();
    for (a, b) in iv {
        if let Some(last) = out.last_mut() {
            if a <= last.1 {
                if b > last.1 {
                    last.1 = b;
                }
                continue;
            }
        }
        out.push((a, b));
    }
    out
}

/// Total length of `a ∩ b` where `b` is a disjoint ascending union.
fn overlap_len(a: &[(f64, f64)], b: &[(f64, f64)]) -> f64 {
    let mut total = 0.0;
    for &(s0, e0) in a {
        for &(s1, e1) in b {
            if e1 <= s0 {
                continue;
            }
            if s1 >= e0 {
                break;
            }
            total += (e0.min(e1) - s0.max(s1)).max(0.0);
        }
    }
    total
}

pub fn bubbles_table(rows: &[BubbleRow]) -> String {
    let mut out = format!("bubbles: {} group(s)\n", rows.len());
    out.push_str(&format!(
        "{:>6} {:>14} {:>14} {:>14} {:>10}\n",
        "gid", "bubble_s", "reclaimed_s", "unreclaimed_s", "reclaimed"
    ));
    for r in rows {
        let frac = if r.bubble_s > 0.0 { r.reclaimed_s / r.bubble_s } else { 0.0 };
        out.push_str(&format!(
            "{:>6} {:>14.3} {:>14.3} {:>14.3} {:>9.1}%\n",
            r.gid,
            r.bubble_s,
            r.reclaimed_s,
            r.unreclaimed_s,
            100.0 * frac
        ));
    }
    out
}

pub fn bubbles_jsonl(rows: &[BubbleRow]) -> String {
    rows.iter()
        .map(|r| {
            obj(vec![
                ("gid", num(r.gid as f64)),
                ("bubble_s", num(r.bubble_s)),
                ("reclaimed_s", num(r.reclaimed_s)),
                ("unreclaimed_s", num(r.unreclaimed_s)),
            ])
            .to_string()
                + "\n"
        })
        .collect()
}

// ------------------------------------------------------------------- explain

/// Every frame in one job's provenance chain, in canonical order: its
/// placement verdict, dispatches, phases, repair fates, SLO-slack
/// samples, and done/repair world events.
pub fn explain<'a>(frames: &'a [Frame], job: JobId) -> Vec<&'a Frame> {
    frames
        .iter()
        .filter(|f| match f {
            Frame::Phase(r) => r.job == job,
            Frame::World(w) => match *w {
                WorldEvent::Done { job: j, .. } | WorldEvent::Repair { job: j, .. } => j == job,
                _ => false,
            },
            Frame::Placement { job: j, .. }
            | Frame::Repair { job: j, .. }
            | Frame::Dispatch { job: j, .. }
            | Frame::SloSlack { job: j, .. } => *j == job,
            Frame::Util { .. } => false,
        })
        .collect()
}

fn phase_kind_name(k: PhaseKind) -> &'static str {
    match k {
        PhaseKind::Init => "init",
        PhaseKind::Rollout => "rollout",
        PhaseKind::Train => "train",
        PhaseKind::Sync => "sync",
    }
}

fn placement_kind_name(tag: u8) -> &'static str {
    match tag {
        0 => "direct-pack",
        1 => "rollout-scale",
        _ => "isolated",
    }
}

/// One-line human rendering of any frame (the `explain` table body).
pub fn frame_line(f: &Frame) -> String {
    match f {
        Frame::Phase(r) => format!(
            "{:>12.3}  phase {:<7} gid {} iter {} dur {:.3}s",
            r.start,
            phase_kind_name(r.kind),
            r.group,
            r.iter,
            r.end - r.start
        ),
        Frame::World(w) => match *w {
            WorldEvent::Done { t, job } => format!("{t:>12.3}  done job {job}"),
            WorldEvent::Crash { t, gid, node } => {
                format!("{t:>12.3}  crash gid {gid} node {node}")
            }
            WorldEvent::Straggle { t, gid, node, factor } => {
                format!("{t:>12.3}  straggle gid {gid} node {node} x{factor:.2}")
            }
            WorldEvent::Repair { t, job, gid, to_gid, repinned } => format!(
                "{t:>12.3}  repair job {job} gid {gid} -> {to_gid}{}",
                if repinned { " (repinned)" } else { "" }
            ),
            WorldEvent::NodeUp { t, gid, node } => {
                format!("{t:>12.3}  node-up gid {gid} node {node}")
            }
        },
        Frame::Util { t, gid, roll_busy_gpu_s, train_busy_gpu_s } => format!(
            "{t:>12.3}  util gid {gid} roll {roll_busy_gpu_s:.3} train {train_busy_gpu_s:.3}"
        ),
        Frame::SloSlack { t, job, iter, slack_s } => {
            format!("{t:>12.3}  slo-slack job {job} iter {iter} slack {slack_s:+.3}s")
        }
        Frame::Placement { t, job, gid, kind_tag, marginal_cost, considered } => {
            let cands: Vec<String> =
                considered.iter().map(|&(g, d)| format!("{g}:{}", fmt_cost(d))).collect();
            format!(
                "{t:>12.3}  placement job {job} -> gid {gid} ({}) cost {} considered [{}]",
                placement_kind_name(*kind_tag),
                fmt_cost(*marginal_cost),
                cands.join(" ")
            )
        }
        Frame::Repair { t, gid, node, job, to_gid, repinned, delay_s } => format!(
            "{t:>12.3}  repair-fate job {job} gid {gid} node {} -> gid {to_gid} {} \
             delay {delay_s:.3}s",
            fmt_id(*node),
            if *repinned { "repinned" } else { "spilled" }
        ),
        Frame::Dispatch { t, gid, job, kind, policy, queue_depth } => format!(
            "{t:>12.3}  dispatch job {job} gid {gid} {} policy {} depth {queue_depth}",
            if *kind == 0 { "rollout" } else { "train" },
            match policy {
                0 => "fifo",
                1 => "rr",
                _ => "slo",
            }
        ),
    }
}

/// Structured rendering of any frame (the `explain` JSONL body). Every
/// object carries a `type` discriminant.
pub fn frame_json(f: &Frame) -> Json {
    match f {
        Frame::Phase(r) => obj(vec![
            ("type", s("phase")),
            ("t", num(r.start)),
            ("job", num(r.job as f64)),
            ("gid", num(r.group as f64)),
            ("kind", s(phase_kind_name(r.kind))),
            ("iter", num(r.iter as f64)),
            ("end", num(r.end)),
        ]),
        Frame::World(w) => match *w {
            WorldEvent::Done { t, job } => {
                obj(vec![("type", s("done")), ("t", num(t)), ("job", num(job as f64))])
            }
            WorldEvent::Crash { t, gid, node } => obj(vec![
                ("type", s("crash")),
                ("t", num(t)),
                ("gid", num(gid as f64)),
                ("node", num(node as f64)),
            ]),
            WorldEvent::Straggle { t, gid, node, factor } => obj(vec![
                ("type", s("straggle")),
                ("t", num(t)),
                ("gid", num(gid as f64)),
                ("node", num(node as f64)),
                ("factor", num(factor)),
            ]),
            WorldEvent::Repair { t, job, gid, to_gid, repinned } => obj(vec![
                ("type", s("repair")),
                ("t", num(t)),
                ("job", num(job as f64)),
                ("gid", num(gid as f64)),
                ("to_gid", num(to_gid as f64)),
                ("repinned", Json::Bool(repinned)),
            ]),
            WorldEvent::NodeUp { t, gid, node } => obj(vec![
                ("type", s("node_up")),
                ("t", num(t)),
                ("gid", num(gid as f64)),
                ("node", num(node as f64)),
            ]),
        },
        Frame::Util { t, gid, roll_busy_gpu_s, train_busy_gpu_s } => obj(vec![
            ("type", s("util")),
            ("t", num(*t)),
            ("gid", num(*gid as f64)),
            ("roll_busy_gpu_s", num(*roll_busy_gpu_s)),
            ("train_busy_gpu_s", num(*train_busy_gpu_s)),
        ]),
        Frame::SloSlack { t, job, iter, slack_s } => obj(vec![
            ("type", s("slo_slack")),
            ("t", num(*t)),
            ("job", num(*job as f64)),
            ("iter", num(*iter as f64)),
            ("slack_s", num(*slack_s)),
        ]),
        Frame::Placement { t, job, gid, kind_tag, marginal_cost, considered } => obj(vec![
            ("type", s("placement")),
            ("t", num(*t)),
            ("job", num(*job as f64)),
            ("gid", num(*gid as f64)),
            ("kind", s(placement_kind_name(*kind_tag))),
            ("marginal_cost", jnum(*marginal_cost)),
            (
                "considered",
                arr(considered
                    .iter()
                    .map(|&(g, d)| arr(vec![num(g as f64), jnum(d)]))
                    .collect()),
            ),
        ]),
        Frame::Repair { t, gid, node, job, to_gid, repinned, delay_s } => obj(vec![
            ("type", s("repair_fate")),
            ("t", num(*t)),
            ("job", num(*job as f64)),
            ("gid", num(*gid as f64)),
            ("node", jnum_id(*node)),
            ("to_gid", num(*to_gid as f64)),
            ("repinned", Json::Bool(*repinned)),
            ("delay_s", num(*delay_s)),
        ]),
        Frame::Dispatch { t, gid, job, kind, policy, queue_depth } => obj(vec![
            ("type", s("dispatch")),
            ("t", num(*t)),
            ("job", num(*job as f64)),
            ("gid", num(*gid as f64)),
            ("kind", s(if *kind == 0 { "rollout" } else { "train" })),
            ("policy", num(*policy as f64)),
            ("queue_depth", num(*queue_depth as f64)),
        ]),
    }
}

pub fn explain_table(job: JobId, frames: &[&Frame]) -> String {
    let mut out = format!("explain job {job}: {} frame(s)\n", frames.len());
    for f in frames {
        out.push_str(&frame_line(f));
        out.push('\n');
    }
    out
}

pub fn explain_jsonl(frames: &[&Frame]) -> String {
    frames.iter().map(|f| frame_json(f).to_string() + "\n").collect()
}

// ---------------------------------------------------------------------- util

/// One utilization sample of a group with deltas to the previous sample.
#[derive(Clone, Debug, PartialEq)]
pub struct UtilRow {
    pub t: f64,
    pub roll_busy_gpu_s: f64,
    pub train_busy_gpu_s: f64,
    pub d_roll: f64,
    pub d_train: f64,
}

/// The cumulative busy-GPU-seconds series of one group, with per-sample
/// deltas (first sample's delta is its absolute value).
pub fn util_series(frames: &[Frame], gid: usize) -> Vec<UtilRow> {
    let mut rows: Vec<UtilRow> = Vec::new();
    for f in frames {
        if let Frame::Util { t, gid: g, roll_busy_gpu_s, train_busy_gpu_s } = *f {
            if g != gid {
                continue;
            }
            let (pr, pt) =
                rows.last().map_or((0.0, 0.0), |r| (r.roll_busy_gpu_s, r.train_busy_gpu_s));
            rows.push(UtilRow {
                t,
                roll_busy_gpu_s,
                train_busy_gpu_s,
                d_roll: roll_busy_gpu_s - pr,
                d_train: train_busy_gpu_s - pt,
            });
        }
    }
    rows
}

pub fn util_table(gid: usize, rows: &[UtilRow]) -> String {
    let mut out = format!("util gid {gid}: {} sample(s)\n", rows.len());
    out.push_str(&format!(
        "{:>12} {:>16} {:>16} {:>12} {:>12}\n",
        "t", "roll_busy_gpu_s", "train_busy_gpu_s", "d_roll", "d_train"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:>12.3} {:>16.3} {:>16.3} {:>12.3} {:>12.3}\n",
            r.t, r.roll_busy_gpu_s, r.train_busy_gpu_s, r.d_roll, r.d_train
        ));
    }
    out
}

pub fn util_jsonl(gid: usize, rows: &[UtilRow]) -> String {
    rows.iter()
        .map(|r| {
            obj(vec![
                ("gid", num(gid as f64)),
                ("t", num(r.t)),
                ("roll_busy_gpu_s", num(r.roll_busy_gpu_s)),
                ("train_busy_gpu_s", num(r.train_busy_gpu_s)),
                ("d_roll", num(r.d_roll)),
                ("d_train", num(r.d_train)),
            ])
            .to_string()
                + "\n"
        })
        .collect()
}

// ---------------------------------------------------------------- histograms

/// Incremental histogram builder over a frame stream: per-job queue
/// wait (the gap between a job's consecutive phases), per-kind phase
/// durations, and SLO slack. Batch queries feed it a whole canonically
/// sorted slice via [`histograms`]; the daemon embeds one and feeds it
/// each fanout drain, so `stats_prom` exposes live distributions whose
/// state is a pure function of the command sequence.
#[derive(Clone, Debug)]
pub struct HistAccum {
    queue: Histogram,
    roll: Histogram,
    train: Histogram,
    sync: Histogram,
    slack: Histogram,
    last_end: BTreeMap<JobId, f64>,
}

impl Default for HistAccum {
    fn default() -> HistAccum {
        HistAccum {
            queue: Histogram::durations("queue_wait_s"),
            roll: Histogram::durations("phase_rollout_s"),
            train: Histogram::durations("phase_train_s"),
            sync: Histogram::durations("phase_sync_s"),
            slack: Histogram::slack("slo_slack_s"),
            last_end: BTreeMap::new(),
        }
    }
}

impl HistAccum {
    pub fn add(&mut self, f: &Frame) {
        match f {
            Frame::Phase(r) => {
                match r.kind {
                    PhaseKind::Rollout => self.roll.add(r.end - r.start),
                    PhaseKind::Train => self.train.add(r.end - r.start),
                    PhaseKind::Sync => self.sync.add(r.end - r.start),
                    PhaseKind::Init => {}
                }
                if let Some(&e) = self.last_end.get(&r.job) {
                    self.queue.add((r.start - e).max(0.0));
                }
                let e = self.last_end.entry(r.job).or_insert(f64::NEG_INFINITY);
                *e = e.max(r.end);
            }
            Frame::SloSlack { slack_s, .. } => self.slack.add(*slack_s),
            _ => {}
        }
    }

    /// Borrow the five histograms (queue wait, rollout, train, sync,
    /// slack) for rendering without consuming the accumulator.
    pub fn hists(&self) -> [&Histogram; 5] {
        [&self.queue, &self.roll, &self.train, &self.sync, &self.slack]
    }

    pub fn into_vec(self) -> Vec<Histogram> {
        vec![self.queue, self.roll, self.train, self.sync, self.slack]
    }
}

/// Fixed-boundary distributions over the stream. One pass in canonical
/// order, so the f64 sums are deterministic.
pub fn histograms(frames: &[Frame]) -> Vec<Histogram> {
    let mut acc = HistAccum::default();
    for f in frames {
        acc.add(f);
    }
    acc.into_vec()
}

pub fn histograms_table(hists: &[Histogram]) -> String {
    hists.iter().map(|h| h.table()).collect()
}

pub fn histograms_jsonl(hists: &[Histogram]) -> String {
    hists.iter().map(|h| h.to_json().to_string() + "\n").collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::PhaseRecord;
    use crate::sim::recorder::canonical_sort_frames;

    fn phase(job: JobId, gid: usize, kind: PhaseKind, start: f64, end: f64) -> Frame {
        Frame::Phase(PhaseRecord { job, group: gid, kind, iter: 0, start, end, roll_nodes: vec![] })
    }

    fn sample_frames() -> Vec<Frame> {
        let mut frames = vec![
            phase(1, 0, PhaseKind::Rollout, 0.0, 100.0),
            phase(1, 0, PhaseKind::Train, 100.0, 160.0),
            phase(2, 0, PhaseKind::Rollout, 120.0, 200.0),
            phase(2, 0, PhaseKind::Train, 210.0, 240.0),
            phase(3, 1, PhaseKind::Train, 50.0, 90.0),
            Frame::SloSlack { t: 150.0, job: 1, iter: 1, slack_s: -12.0 },
            Frame::SloSlack { t: 190.0, job: 2, iter: 1, slack_s: 40.0 },
            Frame::SloSlack { t: 10.0, job: 1, iter: 1, slack_s: -1.0 },
            Frame::World(WorldEvent::Crash { t: 200.0, gid: 0, node: 1 }),
            Frame::Util { t: 160.0, gid: 0, roll_busy_gpu_s: 800.0, train_busy_gpu_s: 480.0 },
            Frame::Util { t: 240.0, gid: 0, roll_busy_gpu_s: 1440.0, train_busy_gpu_s: 720.0 },
        ];
        canonical_sort_frames(&mut frames);
        frames
    }

    #[test]
    fn slo_breach_windows_and_maps_groups() {
        let frames = sample_frames();
        let rows = slo_breach(&frames, 100.0);
        // Only the t=150 breach is within [100, 200]; t=10 is outside.
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].job, 1);
        assert_eq!(rows[0].gid, 0, "job 1 ran in group 0 at t=150");
        assert_eq!(rows[0].crash_t, 200.0);
        assert_eq!(rows[0].slack_s, -12.0);
        // A wide window picks up both breach samples, chronological.
        let wide = slo_breach(&frames, 1000.0);
        assert_eq!(wide.len(), 2);
        assert_eq!(wide[0].slack_t, 10.0);
        let table = slo_breach_table(&rows, 100.0);
        assert!(table.starts_with("slo-breach: window 100s, 1 row(s)\n"));
        let jsonl = slo_breach_jsonl(&rows);
        let parsed = Json::parse(jsonl.trim_end()).unwrap();
        assert_eq!(parsed.get("job").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("slack_s").unwrap().as_f64(), Some(-12.0));
    }

    #[test]
    fn bubbles_attributes_reclaimed_overlap() {
        let frames = sample_frames();
        let rows = bubbles(&frames);
        assert_eq!(rows.len(), 2);
        // Group 0: job 1 trains 100-160, job 2's rollout covers 120-200 →
        // 40 s of job 1's 60 s bubble reclaimed. Job 2 trains 210-240
        // with no other rollout live → unreclaimed.
        let g0 = &rows[0];
        assert_eq!(g0.gid, 0);
        assert_eq!(g0.bubble_s, 90.0);
        assert_eq!(g0.reclaimed_s, 40.0);
        assert_eq!(g0.unreclaimed_s, 50.0);
        // Group 1: a lone trainer, nothing to reclaim with.
        assert_eq!(rows[1].gid, 1);
        assert_eq!(rows[1].reclaimed_s, 0.0);
        assert!(bubbles_table(&rows).contains("bubbles: 2 group(s)"));
        let jsonl = bubbles_jsonl(&rows);
        assert_eq!(jsonl.lines().count(), 2);
    }

    #[test]
    fn explain_filters_one_job_chronologically() {
        let frames = sample_frames();
        let chain = explain(&frames, 1);
        // 2 phases + 2 slack samples; job 2's and group frames excluded.
        assert_eq!(chain.len(), 4);
        assert!(chain.windows(2).all(|w| w[0].t() <= w[1].t()));
        let table = explain_table(1, &chain);
        assert!(table.contains("phase rollout"));
        assert!(table.contains("slo-slack job 1"));
        let jsonl = explain_jsonl(&chain);
        assert_eq!(jsonl.lines().count(), 4);
        let first = Json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("type").unwrap().as_str(), Some("phase"));
    }

    #[test]
    fn util_series_deltas() {
        let frames = sample_frames();
        let rows = util_series(&frames, 0);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].d_roll, 800.0);
        assert_eq!(rows[1].d_roll, 640.0);
        assert_eq!(rows[1].d_train, 240.0);
        assert!(util_series(&frames, 7).is_empty());
        assert!(util_table(0, &rows).contains("util gid 0: 2 sample(s)"));
        let jsonl = util_jsonl(0, &rows);
        let last = Json::parse(jsonl.lines().last().unwrap()).unwrap();
        assert_eq!(last.get("d_train").unwrap().as_f64(), Some(240.0));
    }

    #[test]
    fn histograms_cover_waits_durations_slack() {
        let frames = sample_frames();
        let hists = histograms(&frames);
        assert_eq!(hists.len(), 5);
        let queue = &hists[0];
        assert_eq!(queue.name, "queue_wait_s");
        // Job 1: 100→100 gap 0; job 2: 200→210 gap 10.
        assert_eq!(queue.count, 2);
        assert_eq!(queue.sum, 10.0);
        let train = &hists[2];
        assert_eq!(train.count, 3);
        let slack = &hists[4];
        assert_eq!(slack.count, 3);
        assert!(histograms_table(&hists).contains("slo_slack_s"));
        assert_eq!(histograms_jsonl(&hists).lines().count(), 5);
    }

    #[test]
    fn provenance_frames_render() {
        let f = Frame::Placement {
            t: 5.0,
            job: 9,
            gid: 2,
            kind_tag: 1,
            marginal_cost: 1.25,
            considered: vec![(0, f64::INFINITY), (2, 1.25)],
        };
        let line = frame_line(&f);
        assert!(line.contains("placement job 9 -> gid 2 (rollout-scale)"));
        assert!(line.contains("[0:inf 2:1.250]"));
        let j = frame_json(&f);
        assert_eq!(j.get("kind").unwrap().as_str(), Some("rollout-scale"));
        // Infeasible Δ-cost must serialize as null, not bare `inf`.
        let cands = j.get("considered").unwrap().as_arr().unwrap();
        assert_eq!(cands[0].idx(1), Some(&Json::Null));
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
