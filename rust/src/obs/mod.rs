//! Forensic observability (ISSUE 10, DESIGN.md §18): persisted trace
//! archives over the flight recorder and the query engine behind
//! `rollmux trace`.
//!
//! The flight recorder (`sim/recorder.rs`, DESIGN.md §17) captures what
//! happened; this module makes the stream **outlive the process** and
//! answer **why** questions. [`FlightArchive`] is the `RMTRC01` byte
//! codec — the same fixed-point, length/tag-validated discipline as the
//! `RMSNAP01` snapshot codec, framed per-frame so a daemon can append
//! incrementally and a crash leaves a salvageable file. [`query`] holds
//! the forensic queries (`slo-breach`, `bubbles`, `explain`, `util`,
//! `hist`), each a pure function of the canonically sorted frame slice,
//! so a serial producer, a parallel producer and a daemon-appended
//! archive all answer byte-identically.

pub mod archive;
pub mod query;

pub use archive::{ArchiveWriter, FlightArchive};
