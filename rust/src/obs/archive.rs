//! The `RMTRC01` trace-archive codec (ISSUE 10, DESIGN.md §18).
//!
//! Layout: an 8-byte magic (`RMTRC01\0`), then zero or more frame
//! blocks, each a little-endian `u64` payload length followed by exactly
//! that many bytes of snapshot-codec frame encoding (the same
//! `enc_frame`/`dec_frame` pair `RMSNAP01` images embed, so one frame
//! schema serves both). Per-frame framing is what makes the format
//! daemon-friendly: [`ArchiveWriter`] appends blocks as the fanout
//! drains the recorder, and a `kill -9` mid-write leaves at worst one
//! torn trailing block, which [`FlightArchive::read_salvage`] drops with
//! a counted warning while every complete prefix frame survives.
//!
//! Determinism: `encode` is a pure function of the frame sequence (all
//! words little-endian, f64s as exact bits), so encode→decode→encode is
//! a byte fixed point — property-tested in `rust/tests/prop_trace.rs`
//! alongside the corrupt-tail and trailing-byte rejection cases.

use std::fs::{File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::Path;

use crate::sim::engine::{dec_frame, enc_frame, Dec, Enc};
use crate::sim::recorder::Frame;

/// The 8-byte archive magic: format name + version, NUL-padded.
pub const TRACE_MAGIC: &[u8; 8] = b"RMTRC01\0";

/// Reader/writer for whole-file trace archives.
pub struct FlightArchive;

impl FlightArchive {
    /// Encode a frame sequence into archive bytes (magic included).
    pub fn encode(frames: &[Frame]) -> Vec<u8> {
        let mut out = TRACE_MAGIC.to_vec();
        let mut e = Enc::default();
        for f in frames {
            e.buf.clear();
            enc_frame(&mut e, f);
            out.extend_from_slice(&(e.buf.len() as u64).to_le_bytes());
            out.extend_from_slice(&e.buf);
        }
        out
    }

    /// Strict decode: every block must parse completely (a frame that
    /// leaves unconsumed payload bytes is corrupt, exactly like the
    /// snapshot codec's trailing-byte rejection) and the file must end
    /// on a block boundary.
    pub fn decode(bytes: &[u8]) -> Result<Vec<Frame>, String> {
        let (frames, rest) = decode_prefix(bytes)?;
        if rest != 0 {
            return Err(format!("trace corrupt: {rest} trailing bytes after the last frame"));
        }
        Ok(frames)
    }

    /// Salvage decode for torn daemon tails: parse every complete frame
    /// block and report how many trailing bytes were dropped instead of
    /// failing. Magic and mid-stream corruption still error — only a
    /// clean prefix is salvageable.
    pub fn decode_salvage(bytes: &[u8]) -> Result<(Vec<Frame>, usize), String> {
        decode_prefix(bytes)
    }

    /// Write `frames` as a fresh archive at `path` (atomic enough for
    /// batch use: a full rewrite, not an append).
    pub fn write(path: &Path, frames: &[Frame]) -> io::Result<()> {
        let mut f = File::create(path)?;
        f.write_all(&Self::encode(frames))?;
        f.flush()
    }

    /// Strict whole-file read (the CLI's default).
    pub fn read(path: &Path) -> io::Result<Result<Vec<Frame>, String>> {
        let mut bytes = Vec::new();
        File::open(path)?.read_to_end(&mut bytes)?;
        Ok(Self::decode(&bytes))
    }

    /// Salvaging whole-file read: complete frames plus dropped tail
    /// bytes (0 for a clean archive).
    pub fn read_salvage(path: &Path) -> io::Result<Result<(Vec<Frame>, usize), String>> {
        let mut bytes = Vec::new();
        File::open(path)?.read_to_end(&mut bytes)?;
        Ok(Self::decode_salvage(&bytes))
    }
}

/// Decode every complete frame block; return the frames plus the count
/// of unparseable trailing bytes (a torn final block). Errors on a bad
/// magic or a block whose payload parses wrong despite being complete —
/// that is corruption, not tearing.
fn decode_prefix(bytes: &[u8]) -> Result<(Vec<Frame>, usize), String> {
    let Some(body) = bytes.strip_prefix(TRACE_MAGIC.as_slice()) else {
        return Err("trace corrupt: bad magic (not an RMTRC01 archive)".to_string());
    };
    let mut frames = Vec::new();
    let mut pos = 0usize;
    while pos < body.len() {
        let Some(hdr) = body.get(pos..pos + 8) else {
            return Ok((frames, body.len() - pos)); // torn length word
        };
        let len = u64::from_le_bytes(hdr.try_into().unwrap()) as usize;
        let Some(end) = (pos + 8).checked_add(len) else {
            return Ok((frames, body.len() - pos)); // absurd length word = torn tail
        };
        let Some(payload) = body.get(pos + 8..end) else {
            return Ok((frames, body.len() - pos)); // torn payload
        };
        let mut d = Dec { buf: payload, pos: 0 };
        let f = dec_frame(&mut d)
            .map_err(|e| format!("trace corrupt: frame {} at byte {pos}: {e}", frames.len()))?;
        if d.pos != payload.len() {
            return Err(format!(
                "trace corrupt: frame {} leaves {} unconsumed payload bytes",
                frames.len(),
                payload.len() - d.pos
            ));
        }
        frames.push(f);
        pos += 8 + len;
    }
    Ok((frames, 0))
}

/// Incremental archive appender for rollmuxd's `--trace` flag: blocks go
/// out as the fanout drains the recorder, so a crashed daemon leaves an
/// archive that reads back up to its last flushed frame.
pub struct ArchiveWriter {
    file: File,
}

impl ArchiveWriter {
    /// Create (or truncate) an archive at `path` and stamp the magic.
    pub fn create(path: &Path) -> io::Result<ArchiveWriter> {
        let mut file = File::create(path)?;
        file.write_all(TRACE_MAGIC)?;
        file.flush()?;
        Ok(ArchiveWriter { file })
    }

    /// Open an existing archive for appending, validating the magic (a
    /// restarted daemon continues the file its predecessor left).
    /// Creates a fresh archive when the file does not exist.
    pub fn open_append(path: &Path) -> io::Result<ArchiveWriter> {
        match File::open(path) {
            Err(e) if e.kind() == io::ErrorKind::NotFound => Self::create(path),
            Err(e) => Err(e),
            Ok(mut f) => {
                let mut magic = [0u8; 8];
                f.read_exact(&mut magic)
                    .map_err(|_| io::Error::other("trace archive shorter than its magic"))?;
                if &magic != TRACE_MAGIC {
                    return Err(io::Error::other("not an RMTRC01 trace archive"));
                }
                drop(f);
                let file = OpenOptions::new().append(true).open(path)?;
                Ok(ArchiveWriter { file })
            }
        }
    }

    /// Append one batch of frames and flush, so every fanout's frames
    /// survive a subsequent crash.
    pub fn append(&mut self, frames: &[Frame]) -> io::Result<()> {
        if frames.is_empty() {
            return Ok(());
        }
        let mut block = Vec::new();
        let mut e = Enc::default();
        for f in frames {
            e.buf.clear();
            enc_frame(&mut e, f);
            block.extend_from_slice(&(e.buf.len() as u64).to_le_bytes());
            block.extend_from_slice(&e.buf);
        }
        self.file.write_all(&block)?;
        self.file.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::WorldEvent;

    fn frames() -> Vec<Frame> {
        vec![
            Frame::World(WorldEvent::Crash { t: 10.0, gid: 1, node: 0 }),
            Frame::SloSlack { t: 11.0, job: 3, iter: 2, slack_s: -4.5 },
            Frame::Placement {
                t: 12.0,
                job: 4,
                gid: 1,
                kind_tag: 0,
                marginal_cost: 0.0,
                considered: vec![(0, f64::INFINITY), (1, 0.0)],
            },
            Frame::Dispatch { t: 13.0, gid: 1, job: 4, kind: 0, policy: 2, queue_depth: 2 },
            Frame::Repair {
                t: 14.0,
                gid: 1,
                node: 0,
                job: 3,
                to_gid: 2,
                repinned: false,
                delay_s: 120.0,
            },
        ]
    }

    #[test]
    fn encode_decode_is_fixed_point() {
        let fs = frames();
        let bytes = FlightArchive::encode(&fs);
        let back = FlightArchive::decode(&bytes).expect("decode");
        assert_eq!(back, fs);
        assert_eq!(FlightArchive::encode(&back), bytes, "fixed point");
    }

    #[test]
    fn trailing_and_torn_bytes() {
        let fs = frames();
        let mut bytes = FlightArchive::encode(&fs);
        bytes.push(0x5a);
        assert!(FlightArchive::decode(&bytes).is_err(), "strict rejects trailing byte");
        let (got, dropped) = FlightArchive::decode_salvage(&bytes).expect("salvage");
        assert_eq!(got, fs);
        assert_eq!(dropped, 1);
        // Tear mid-payload: strict rejects, salvage drops the last frame.
        let clean = FlightArchive::encode(&fs);
        let torn = &clean[..clean.len() - 3];
        assert!(FlightArchive::decode(torn).is_err());
        let (got, dropped) = FlightArchive::decode_salvage(torn).expect("salvage");
        assert_eq!(got, fs[..fs.len() - 1]);
        assert!(dropped > 0);
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(FlightArchive::decode(b"NOTMAGIC").is_err());
        assert!(FlightArchive::decode_salvage(b"NOTMAGIC").is_err());
    }

    #[test]
    fn writer_appends_restart_safe() {
        let dir = std::env::temp_dir().join(format!("rollmux_trc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.rmtrc");
        let fs = frames();
        {
            let mut w = ArchiveWriter::create(&path).unwrap();
            w.append(&fs[..2]).unwrap();
        }
        {
            let mut w = ArchiveWriter::open_append(&path).unwrap();
            w.append(&fs[2..]).unwrap();
        }
        let got = FlightArchive::read(&path).unwrap().expect("clean archive");
        assert_eq!(got, fs);
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir(&dir).ok();
    }
}
