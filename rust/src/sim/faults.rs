//! Deterministic fault-trace generation — the chaos tier's event source
//! (ISSUE 5, DESIGN.md §13).
//!
//! At 328+328-GPU production scale node failures and stragglers are
//! routine, but until this PR the simulator was a closed world: no
//! component could lose a node, evict a resident, or heal a group. This
//! module supplies the *inputs* of that axis: a seeded, deterministic
//! stream of fault events (node crashes with sampled repair times,
//! straggler slowdowns) driven by configurable MTBF and repair-time
//! distributions. Both simulation tiers consume the identical stream:
//!
//!  * the exact engine ([`super::engine::Simulator`]) files each fault on
//!    its calendar queue and applies it event-exactly (interrupt, heal,
//!    recover);
//!  * the fluid tier ([`super::fluid::FluidSimulator`]) applies the same
//!    events as piecewise rate changes at group-recheck boundaries.
//!
//! Victim selection is *state-resolved*: an event carries an opaque
//! `victim` draw, and [`crate::coordinator::repair::pick_victim`] maps it
//! onto the provisioned node set at the moment the event fires. The
//! stream itself never references group ids (groups are provisioned on
//! demand), so one fault trace is meaningful against any scheduler
//! state — and with `SimConfig::faults = None` (or an empty stream) both
//! tiers are **bitwise identical** to the fault-free engine
//! (property-tested in `rust/tests/prop_faults.rs`).

use crate::util::rng::Rng;

/// What a fault event does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// A rollout node dies: its host-DRAM residency is lost (every pinned
    /// member cold-restarts), the group heals around it
    /// (`coordinator::repair`), and the node returns after `repair_s`.
    NodeCrash { repair_s: f64 },
    /// A node straggles: in-flight rollouts touching it run `factor`×
    /// slower for the remainder of the phase (no state is lost).
    Straggler { factor: f64 },
}

/// One fault, in simulated time. `victim` is resolved against the live
/// cluster state when the event fires (see module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    pub victim: u64,
    pub kind: FaultKind,
}

/// Fault-model knobs (`SimConfig::faults`). `mtbf_s` is the fleet-wide
/// mean time between fault events (exponential inter-arrival): at
/// production scale MTBF shrinks with node count, so sweeps vary this
/// directly instead of a per-node rate.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Seed of the fault stream (independent of the workload seed).
    pub seed: u64,
    /// Mean time between fault events, seconds. Non-finite or <= 0
    /// disables the stream entirely (zero events).
    pub mtbf_s: f64,
    /// Mean node repair time, seconds (exponential).
    pub mean_repair_s: f64,
    /// Fraction of events that are straggler slowdowns instead of
    /// crashes.
    pub straggler_frac: f64,
    /// Straggler slowdown multiplier (>1).
    pub straggler_factor: f64,
    /// Hard cap on generated events (safety valve for open-ended runs).
    pub max_events: usize,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0xFA17,
            mtbf_s: 4.0 * 3600.0,
            mean_repair_s: 600.0,
            straggler_frac: 0.25,
            straggler_factor: 1.5,
            max_events: 1_000_000,
        }
    }
}

impl FaultConfig {
    /// A config whose stream is empty — the zero-fault anchor used by the
    /// equivalence tests (`Some(empty)` must be bitwise `None`).
    pub fn empty() -> Self {
        FaultConfig { max_events: 0, ..Default::default() }
    }

    /// Convenience: the default fault mix at a given MTBF.
    pub fn with_mtbf(seed: u64, mtbf_s: f64) -> Self {
        FaultConfig { seed, mtbf_s, ..Default::default() }
    }
}

/// The seeded fault stream: an iterator over [`FaultEvent`]s with
/// strictly non-decreasing times. Both tiers pull it lazily (one event
/// ahead), so the stream length adapts to the trace's makespan without a
/// horizon guess.
#[derive(Clone, Debug)]
pub struct FaultTraceGen {
    cfg: FaultConfig,
    rng: Rng,
    t: f64,
    emitted: usize,
}

impl FaultTraceGen {
    pub fn new(cfg: FaultConfig) -> Self {
        let rng = Rng::new(cfg.seed ^ 0xC4A0_5EED_0000_0001);
        FaultTraceGen { cfg, rng, t: 0.0, emitted: 0 }
    }

    /// Capture the generator's mutable state for a checkpoint (DESIGN.md
    /// §17): the RNG parts, the running clock and the emitted count. The
    /// `FaultConfig` is *not* part of the capture — restore re-supplies it
    /// (configs are caller-owned inputs, like `SimConfig`).
    pub fn snapshot_parts(&self) -> ((u64, u64), f64, usize) {
        (self.rng.to_parts(), self.t, self.emitted)
    }

    /// Rebuild a generator mid-stream from [`Self::snapshot_parts`]; the
    /// restored stream continues bit-exactly.
    pub fn from_parts(cfg: FaultConfig, rng: (u64, u64), t: f64, emitted: usize) -> Self {
        FaultTraceGen { cfg, rng: Rng::from_parts(rng.0, rng.1), t, emitted }
    }
}

impl Iterator for FaultTraceGen {
    type Item = FaultEvent;

    fn next(&mut self) -> Option<FaultEvent> {
        if self.emitted >= self.cfg.max_events {
            return None;
        }
        if !(self.cfg.mtbf_s.is_finite() && self.cfg.mtbf_s > 0.0) {
            return None;
        }
        self.t += self.rng.exponential(self.cfg.mtbf_s);
        let victim = self.rng.next_u64();
        let kind = if self.rng.chance(self.cfg.straggler_frac) {
            FaultKind::Straggler { factor: self.cfg.straggler_factor.max(1.0) }
        } else {
            FaultKind::NodeCrash {
                repair_s: self.rng.exponential(self.cfg.mean_repair_s.max(1e-9)),
            }
        };
        self.emitted += 1;
        Some(FaultEvent { t: self.t, victim, kind })
    }
}

/// Materialize the fault stream up to a horizon (offline analysis and
/// the `workload::trace` surface; the simulators pull the generator
/// lazily instead).
pub fn fault_trace(cfg: &FaultConfig, horizon_s: f64) -> Vec<FaultEvent> {
    FaultTraceGen::new(cfg.clone()).take_while(|e| e.t <= horizon_s).collect()
}

/// The simulators' lazily-pulled stream wrapper (shared by both tiers —
/// the chaining rule lives here exactly once): at most ONE event is in
/// flight at a time, identified by a monotone handle the calendar event
/// carries. Memory is O(1) — fired events are not retained.
#[derive(Clone, Debug)]
pub struct FaultStream {
    gen: FaultTraceGen,
    handed_out: usize,
    pending: Option<FaultEvent>,
}

impl FaultStream {
    /// Arm a stream from `SimConfig::faults` (`None` stays `None`).
    pub fn arm(cfg: Option<&FaultConfig>) -> Option<FaultStream> {
        cfg.map(|fc| FaultStream {
            gen: FaultTraceGen::new(fc.clone()),
            handed_out: 0,
            pending: None,
        })
    }

    /// Pull the next event into the pending slot; returns the calendar
    /// handle and fire time, or `None` when the stream is exhausted.
    pub fn pull(&mut self) -> Option<(usize, f64)> {
        let e = self.gen.next()?;
        self.pending = Some(e);
        let handle = self.handed_out;
        self.handed_out += 1;
        Some((handle, e.t))
    }

    /// Resolve a calendar handle back to its event (exactly one is ever
    /// in flight, so the handle must be the most recent).
    pub fn event(&self, handle: usize) -> FaultEvent {
        debug_assert_eq!(handle + 1, self.handed_out, "one fault event in flight at a time");
        self.pending.expect("pending fault event")
    }

    /// Capture the stream's mutable state for a checkpoint: the wrapped
    /// generator's parts, the handle counter, and the pending event.
    pub fn snapshot_parts(&self) -> (((u64, u64), f64, usize), usize, Option<FaultEvent>) {
        (self.gen.snapshot_parts(), self.handed_out, self.pending)
    }

    /// Rebuild a stream mid-flight from [`Self::snapshot_parts`].
    pub fn from_parts(
        cfg: FaultConfig,
        gen: ((u64, u64), f64, usize),
        handed_out: usize,
        pending: Option<FaultEvent>,
    ) -> Self {
        FaultStream {
            gen: FaultTraceGen::from_parts(cfg, gen.0, gen.1, gen.2),
            handed_out,
            pending,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic_and_monotone() {
        let cfg = FaultConfig::with_mtbf(9, 1800.0);
        let a: Vec<FaultEvent> = FaultTraceGen::new(cfg.clone()).take(500).collect();
        let b: Vec<FaultEvent> = FaultTraceGen::new(cfg).take(500).collect();
        assert_eq!(a.len(), 500);
        assert_eq!(a, b, "same seed must replay the same stream");
        assert!(a.windows(2).all(|w| w[0].t <= w[1].t), "times non-decreasing");
        assert!(a.iter().all(|e| e.t > 0.0));
    }

    #[test]
    fn mtbf_controls_event_rate() {
        let horizon = 1_000.0 * 3600.0;
        let slow = fault_trace(&FaultConfig::with_mtbf(3, 10.0 * 3600.0), horizon);
        let fast = fault_trace(&FaultConfig::with_mtbf(3, 3600.0), horizon);
        // ~100 vs ~1000 events over 1000 h.
        assert!((60..160).contains(&slow.len()), "slow stream {} events", slow.len());
        assert!((800..1200).contains(&fast.len()), "fast stream {} events", fast.len());
    }

    #[test]
    fn mix_has_both_kinds_and_sane_params() {
        let evs = fault_trace(&FaultConfig::with_mtbf(5, 600.0), 2_000.0 * 600.0);
        let crashes = evs
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::NodeCrash { .. }))
            .count();
        let stragglers = evs.len() - crashes;
        assert!(crashes > 0 && stragglers > 0, "{crashes} crashes / {stragglers} stragglers");
        // Default mix: ~25% stragglers.
        let frac = stragglers as f64 / evs.len() as f64;
        assert!((0.15..0.35).contains(&frac), "straggler frac {frac}");
        for e in &evs {
            match e.kind {
                FaultKind::NodeCrash { repair_s } => assert!(repair_s >= 0.0),
                FaultKind::Straggler { factor } => assert!(factor >= 1.0),
            }
        }
    }

    #[test]
    fn empty_and_disabled_streams_yield_nothing() {
        assert_eq!(FaultTraceGen::new(FaultConfig::empty()).next(), None);
        let off = FaultConfig { mtbf_s: f64::INFINITY, ..Default::default() };
        assert_eq!(FaultTraceGen::new(off).next(), None);
        let neg = FaultConfig { mtbf_s: -1.0, ..Default::default() };
        assert_eq!(FaultTraceGen::new(neg).next(), None);
    }

    #[test]
    fn max_events_caps_the_stream() {
        let cfg = FaultConfig { max_events: 7, ..FaultConfig::with_mtbf(1, 60.0) };
        assert_eq!(FaultTraceGen::new(cfg).count(), 7);
    }

    #[test]
    fn stream_snapshot_resumes_bitwise() {
        let cfg = FaultConfig::with_mtbf(21, 300.0);
        let mut live = FaultStream::arm(Some(&cfg)).unwrap();
        for _ in 0..5 {
            live.pull().unwrap();
        }
        let (gen, handed_out, pending) = live.snapshot_parts();
        let mut restored = FaultStream::from_parts(cfg, gen, handed_out, pending);
        assert_eq!(restored.event(handed_out - 1), live.event(handed_out - 1));
        for _ in 0..50 {
            let a = live.pull();
            let b = restored.pull();
            match (a, b) {
                (Some((ha, ta)), Some((hb, tb))) => {
                    assert_eq!(ha, hb);
                    assert_eq!(ta.to_bits(), tb.to_bits());
                    assert_eq!(live.event(ha), restored.event(hb));
                }
                (a, b) => assert_eq!(a.is_none(), b.is_none()),
            }
        }
    }

    #[test]
    fn fault_stream_hands_out_one_pending_event() {
        assert!(FaultStream::arm(None).is_none());
        let cfg = FaultConfig::with_mtbf(2, 100.0);
        let mut s = FaultStream::arm(Some(&cfg)).unwrap();
        let direct: Vec<FaultEvent> = FaultTraceGen::new(cfg).take(3).collect();
        for (i, want) in direct.iter().enumerate() {
            let (h, t) = s.pull().unwrap();
            assert_eq!(h, i, "handles are monotone");
            assert_eq!(t.to_bits(), want.t.to_bits(), "same stream as the raw generator");
            assert_eq!(s.event(h), *want);
        }
    }
}
