//! ASCII gantt rendering of a simulated timeline (the left panels of the
//! paper's Fig. 10), plus JSON export for offline plotting.

use std::collections::BTreeMap;

use super::engine::{PhaseKind, PhaseRecord};
use crate::util::json::{arr, num, obj, s, Json};

/// Render a per-resource gantt chart. Each row is one resource lane
/// (rollout node or a group's train pool); time is bucketed into `width`
/// columns; cells show the job id (letter) running there.
pub fn render(records: &[PhaseRecord], width: usize) -> String {
    if records.is_empty() {
        return "(empty timeline)\n".to_string();
    }
    let t_end = records.iter().map(|r| r.end).fold(0.0, f64::max);
    let t0 = 0.0;
    let scale = (t_end - t0) / width as f64;

    // lane key -> label
    let mut lanes: BTreeMap<String, Vec<(f64, f64, char)>> = BTreeMap::new();
    for r in records {
        let glyph = job_glyph(r.job);
        match r.kind {
            PhaseKind::Rollout => {
                for &n in &r.roll_nodes {
                    lanes
                        .entry(format!("g{}/roll{:02}", r.group, n))
                        .or_default()
                        .push((r.start, r.end, glyph));
                }
            }
            PhaseKind::Train => {
                lanes
                    .entry(format!("g{}/train ", r.group))
                    .or_default()
                    .push((r.start, r.end, glyph));
            }
            _ => {}
        }
    }

    let mut out = String::new();
    out.push_str(&format!(
        "gantt: {:.0}s total, one column = {:.1}s; lanes are resources, letters are jobs\n",
        t_end, scale
    ));
    for (label, spans) in lanes {
        let mut row = vec!['.'; width];
        for (start, end, glyph) in spans {
            let a = ((start - t0) / scale) as usize;
            let b = (((end - t0) / scale).ceil() as usize).min(width);
            for c in row.iter_mut().take(b).skip(a.min(width)) {
                *c = glyph;
            }
        }
        out.push_str(&format!("{label:>12} |{}|\n", row.iter().collect::<String>()));
    }
    out
}

fn job_glyph(job: usize) -> char {
    let alphabet = "ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz";
    alphabet.chars().nth(job % alphabet.len()).unwrap()
}

/// JSON export of the raw timeline (for external plotting).
pub fn to_json(records: &[PhaseRecord]) -> Json {
    arr(records
        .iter()
        .map(|r| {
            obj(vec![
                ("job", num(r.job as f64)),
                ("group", num(r.group as f64)),
                (
                    "kind",
                    s(match r.kind {
                        PhaseKind::Init => "init",
                        PhaseKind::Rollout => "rollout",
                        PhaseKind::Train => "train",
                        PhaseKind::Sync => "sync",
                    }),
                ),
                ("iter", num(r.iter as f64)),
                ("start", num(r.start)),
                ("end", num(r.end)),
                (
                    "roll_nodes",
                    arr(r.roll_nodes.iter().map(|&n| num(n as f64)).collect()),
                ),
            ])
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(job: usize, kind: PhaseKind, start: f64, end: f64, nodes: Vec<usize>) -> PhaseRecord {
        PhaseRecord { job, group: 0, kind, iter: 0, start, end, roll_nodes: nodes }
    }

    #[test]
    fn renders_lanes() {
        let records = vec![
            rec(0, PhaseKind::Rollout, 0.0, 50.0, vec![0]),
            rec(0, PhaseKind::Train, 50.0, 80.0, vec![]),
            rec(1, PhaseKind::Rollout, 50.0, 100.0, vec![0]),
        ];
        let g = render(&records, 20);
        assert!(g.contains("g0/roll00"));
        assert!(g.contains("g0/train"));
        assert!(g.contains('A') && g.contains('B'));
    }

    #[test]
    fn json_roundtrip() {
        let records = vec![rec(3, PhaseKind::Sync, 1.0, 2.0, vec![])];
        let j = to_json(&records);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.idx(0).unwrap().get("kind").unwrap().as_str(), Some("sync"));
    }

    #[test]
    fn empty_timeline() {
        assert!(render(&[], 10).contains("empty"));
    }
}
