//! The fluid simulation tier (DESIGN.md §12) — Tier B of ISSUE 4.
//!
//! The exact engine (`sim::engine`) replays every phase of every
//! iteration as a discrete event: a 100k-job fleet trace is tens of
//! millions of events even with the calendar queue. This module trades
//! event exactness for a **bounded-error closed form**: between
//! scheduler decision points (arrivals, init completions, job
//! completions) every co-execution group advances at a piecewise-
//! constant iteration rate, skipping intra-cycle events entirely.
//!
//! Model. For an unsaturated group under any work-conserving dispatch
//! order, the steady-state meta-iteration period is (Theorem 1, extended
//! with the switch costs the engine actually pays)
//!
//! ```text
//! P(G) = max( max_j  warm_roll_j + roll_j + warm_train_j + train_j + sync_j ,
//!             max_n  Σ_{j pinned to n} (warm_roll_j + roll_j) ,
//!                    Σ_j (warm_train_j + train_j) )
//! ```
//!
//! — the longest member path, the busiest rollout node, or the serial
//! training queue, whichever gates. Every member completes one
//! iteration per `P`, so a member's finish time is its activation time
//! plus `remaining_iters × P`, re-evaluated whenever membership changes.
//!
//! Exactness anchors (what keeps the error ≤2% on the property-test
//! traces, `rust/tests/prop_fluid.rs`):
//!
//! * **Per-job durations replay the exact engine's RNG streams.** At
//!   admission the fluid tier walks the job's per-job PRNG stream the
//!   same way the engine does — one `sample_iter` plus the two
//!   tail-shape forks per iteration — so the per-iteration *means* it
//!   rates on (and the reported `solo_actual_s`) are bit-identical to
//!   what the exact engine realizes, for any `PhaseSpec`.
//! * **Busy integrals are progress-proportional.** Rollout/train busy
//!   GPU-seconds accrue as `Δiters × occupancy`, so a completed job
//!   contributes exactly its engine total (`n_iters × (warm + mean)`),
//!   and the streaming per-(group, node) accumulators stay comparable.
//! * **Join transients are modeled, not ignored.** A job entering an
//!   occupied rotation waits about half the residual occupancy of its
//!   pinned nodes before its first rollout; the fluid tier charges
//!   `0.5 × shared-node load` between init end and rotation entry,
//!   centering the one-cycle phase-in error the pure closed form has.
//!
//! Out of scope (documented soundness limits, DESIGN.md §12): long-tail
//! migration (its pauses and sub-node tails are not modeled; `Fluid`
//! reports zero migrations), per-round jitter of the cycle maximum
//! (`E[max] ≥ max[E]` — the fluid period uses per-job means, so traces
//! with high `cv` and near-equal co-members bias a few percent fast),
//! and gantt records (`record_gantt` yields no `PhaseRecord`s — there
//! are no per-phase events to record).
//!
//! **Chaos tier (ISSUE 5, DESIGN.md §13).** The same fault stream the
//! exact engine replays event-exactly is applied here as piecewise rate
//! changes at group-recheck boundaries: a node crash advances the
//! damaged group, rolls every victim back to its last iteration
//! checkpoint (the discarded fraction is wasted work), heals the group
//! through `coordinator::repair` (repin / spill), and suspends victims
//! from their rotation for the recovery delay — the group's period is
//! recomputed without them, rising again when they rejoin. Stragglers
//! suspend the affected members for the slowdown overhead instead.
//! Fluid fault semantics are approximate by design: the crashed node
//! itself is treated as hot-spared (no down window — sound when
//! `repair_s ≪ MTBF`), and per-phase interruption detail is folded into
//! the one-iteration rollback. With `SimConfig::faults = None` (or an
//! empty stream) this tier stays bitwise identical to its fault-free
//! behavior (property-tested).
//!
//! **Streaming trace consumption (ISSUE 7, DESIGN.md §15).** A million-
//! job sweep cannot afford the whole `Vec<JobSpec>`: the trace now lives
//! in an [`ArrivalStore`] that compacts settled arrivals away, and a
//! driver can interleave [`FluidSimulator::feed`] /
//! [`FluidSimulator::advance_to`] to hold only the in-flight window
//! (O(concurrent jobs), not O(trace)). The split sequence scheme
//! ([`DYN_SEQ_BASE`]) makes the streamed run bitwise identical to the
//! batch constructor for the same job sequence — chaos included.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

use crate::cluster::node::GPUS_PER_NODE;
use crate::coordinator::inter::Decision;
use crate::coordinator::repair::{self, MemberFate};
use crate::sync::sync_time_s;
use crate::util::rng::Rng;
use crate::workload::job::{JobId, JobSpec, PhaseSpec};

use super::arena::ArrivalStore;
use super::engine::{GroupScheduler, JobOutcome, SimConfig, SimResult};
use super::faults::{FaultKind, FaultStream};

/// Snap-to-completion tolerance, in iterations: absorbs the fp rounding
/// of `(remaining × P) / P`.
const EPS_ITERS: f64 = 1e-6;

/// Dynamic-event sequence base (ISSUE 7 streaming). Arrival events take
/// `arrival_index + 1` as their tie-break sequence; every event the run
/// generates (joins, rechecks, faults) draws from a counter starting
/// here. The split keeps the heap's (t, seq) total order independent of
/// WHEN arrivals are fed: a batch load (all arrivals up front) and a
/// chunked stream interleaving `feed` with `advance_to` assign identical
/// keys to every event, so the two are bitwise identical (pinned by
/// `streaming_feed_matches_batch_bitwise`). 2^48 arrivals is the
/// resulting trace-length ceiling — five orders of magnitude above the
/// 1M-job sweeps.
const DYN_SEQ_BASE: u64 = 1 << 48;

#[derive(Clone, Copy, Debug, PartialEq)]
enum FEv {
    /// Index into the trace.
    Arrival(usize),
    /// Cold init (+ modeled phase-in wait) done — or a fault suspension
    /// elapsed: the job (re-)enters its group's rotation. Carries the
    /// job's slab slot and restart epoch (a fault bumps the epoch, so a
    /// superseded join is recognized as stale; always 0 without faults).
    Join(usize, u32),
    /// Predicted next completion inside a group: (group id, version at
    /// scheduling time — stale checks discard outdated predictions).
    Recheck(usize, u64),
    /// Apply generated fault `events[idx]` (ISSUE 5).
    Fault(usize),
}

#[derive(Clone, Debug)]
struct FEvent {
    t: f64,
    seq: u64,
    ev: FEv,
}

impl PartialEq for FEvent {
    fn eq(&self, o: &Self) -> bool {
        self.t.total_cmp(&o.t) == Ordering::Equal && self.seq == o.seq
    }
}
impl Eq for FEvent {}
impl PartialOrd for FEvent {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for FEvent {
    fn cmp(&self, o: &Self) -> Ordering {
        // Min-heap on (time, seq) — the engine's exact total order.
        o.t.total_cmp(&self.t).then(o.seq.cmp(&self.seq))
    }
}

/// Fluid per-job state (dense slab, arrival order).
struct FluidJob {
    id: JobId,
    gid: usize,
    roll_nodes: Vec<usize>,
    train_gpus: usize,
    /// Mean per-iteration actual durations from the exact RNG replay.
    occ_roll: f64,
    occ_train: f64,
    /// Hierarchical sync time per iteration (depends on the group's
    /// training pool; recomputed on spill).
    t_sync: f64,
    /// Member path: `occ_roll + occ_train + t_sync`.
    path: f64,
    /// Effective iterations (the engine always runs at least one).
    n_eff: usize,
    done_iters: f64,
    finished: bool,
    /// Restart epoch (ISSUE 5): bumped on fault suspension; stale Join
    /// events are dropped. Always 0 without faults.
    epoch: u32,
    // Spill re-rating inputs (a new group's training pool changes the
    // DP rescale and sync time; the canonical solo replay is untouched).
    params_b: f64,
    warm_train: f64,
    mean_train_raw: f64,
    direct: bool,
    n_roll_gpus: usize,
    spec_train_gpus: usize,
    model_bytes: f64,
    // Chaos accounting mirrored into the JobOutcome.
    recoveries: usize,
    recovery_s: f64,
    // Outcome bookkeeping.
    arrival_s: f64,
    slo: f64,
    n_iters_raw: usize,
    solo_actual_s: f64,
    solo_est_iter_s: f64,
    init_s: f64,
}

impl FluidJob {
    fn remaining(&self) -> f64 {
        (self.n_eff as f64 - self.done_iters).max(0.0)
    }
}

/// Fluid per-group state (indexed by scheduler group id; ids are
/// monotone and never reused).
#[derive(Default)]
struct FluidGroup {
    /// Slots currently in the rotation (joined, unfinished).
    members: Vec<usize>,
    /// Slots admitted by the scheduler and not yet finished (includes
    /// jobs still in init) — the join-delay estimate scans these.
    admitted: Vec<usize>,
    last_t: f64,
    /// Current meta-iteration period `P`; meaningless while empty.
    period: f64,
    /// Bumped on every membership/period change; rechecks carrying an
    /// older version are stale.
    version: u64,
}

/// The fluid simulator: same inputs and `SimResult` surface as the exact
/// [`super::engine::Simulator`], selected via `SimConfig::fidelity`
/// (use [`super::engine::run_sim`]).
pub struct FluidSimulator<S: GroupScheduler> {
    pub cfg: SimConfig,
    pub sched: S,
    /// Pending arrivals, dense-indexed; settled prefix compacts away so
    /// a streamed run holds only the in-flight window (ISSUE 7).
    trace: ArrivalStore<JobSpec>,
    /// `true` once the trace is complete: no further `feed` calls. Batch
    /// construction seals immediately; streams seal via [`Self::seal`].
    sealed: bool,
    events: BinaryHeap<FEvent>,
    seq: u64,
    now: f64,
    jobs: Vec<FluidJob>,
    /// job id -> slab slot (the fault layer resolves repair outcomes).
    job_slot: HashMap<JobId, usize>,
    /// Armed fault stream (None without `cfg.faults`).
    faults_rt: Option<FaultStream>,
    groups: Vec<FluidGroup>,
    res: SimResult,
    // Cost integration state (mirrors the exact engine).
    last_rate_change: f64,
    cur_rate_per_h: f64,
    cur_roll_gpus: usize,
    cur_train_gpus: usize,
    // Reusable scratch: Roofline length batches + per-node load folds.
    scratch_lengths: Vec<f64>,
    scratch_node_load: Vec<f64>,
}

impl<S: GroupScheduler> FluidSimulator<S> {
    pub fn new(cfg: SimConfig, sched: S, trace: Vec<JobSpec>) -> Self {
        let mut sim = Self::open_stream(cfg, sched);
        for spec in trace {
            sim.feed(spec);
        }
        sim.seal();
        sim
    }

    /// Open a streaming run (ISSUE 7): no trace yet — the driver
    /// interleaves [`Self::feed`] and [`Self::advance_to`], then calls
    /// [`Self::seal`] and [`Self::run_to_end`]. Bitwise identical to the
    /// batch constructor for the same job sequence.
    pub fn open_stream(cfg: SimConfig, sched: S) -> Self {
        let mut sim = FluidSimulator {
            cfg,
            sched,
            trace: ArrivalStore::new(),
            sealed: false,
            events: BinaryHeap::new(),
            seq: DYN_SEQ_BASE,
            now: 0.0,
            jobs: Vec::new(),
            job_slot: HashMap::new(),
            faults_rt: None,
            groups: Vec::new(),
            res: SimResult::default(),
            last_rate_change: 0.0,
            cur_rate_per_h: 0.0,
            cur_roll_gpus: 0,
            cur_train_gpus: 0,
            scratch_lengths: Vec::new(),
            scratch_node_load: Vec::new(),
        };
        sim.arm_faults();
        sim
    }

    fn arm_faults(&mut self) {
        // Arm the chaos stream (one event in flight, lazily chained).
        self.faults_rt = FaultStream::arm(self.cfg.faults.as_ref());
        if let Some((h, t)) = self.faults_rt.as_mut().and_then(FaultStream::pull) {
            self.push(t, FEv::Fault(h));
        }
    }

    /// Append the next arrival to the stream. Arrivals must be fed in
    /// trace order; feeding after [`Self::seal`] is a bug.
    pub fn feed(&mut self, spec: JobSpec) {
        assert!(!self.sealed, "feed after seal");
        let t = spec.arrival_s;
        let idx = self.trace.push(spec);
        debug_assert!((idx as u64) < DYN_SEQ_BASE - 1, "trace exceeds the arrival seq space");
        // Arrival tie-break seqs are the dense index: identical whether
        // the trace was loaded up front or streamed in chunks.
        self.events.push(FEvent { t, seq: idx as u64 + 1, ev: FEv::Arrival(idx) });
    }

    /// Declare the stream complete: every job has been fed. Settled-world
    /// guards (fault events outliving the workload) activate only now.
    pub fn seal(&mut self) {
        self.sealed = true;
    }

    /// In-flight arrivals still held by the store (diagnostics: a
    /// streamed run's memory window, O(concurrent jobs) not O(trace)).
    pub fn stream_window(&self) -> usize {
        self.trace.window_len()
    }

    /// Process every event strictly before `horizon`. The caller must
    /// have fed all arrivals with `arrival_s < horizon`; events at
    /// exactly `horizon` stay queued so a not-yet-fed arrival at that
    /// instant keeps its place in the total order.
    pub fn advance_to(&mut self, horizon: f64) {
        while let Some(e) = self.events.peek() {
            if e.t >= horizon {
                break;
            }
            let e = self.events.pop().expect("peeked event");
            self.step(e);
        }
    }

    /// Rearm for another run, reusing the slabs (sweep drivers; the
    /// exact-tier counterpart is `Simulator::reset_with_trace`).
    pub fn reset_with_trace(&mut self, cfg: SimConfig, sched: S, trace: Vec<JobSpec>) {
        self.reset_stream(cfg, sched);
        for spec in trace {
            self.feed(spec);
        }
        self.seal();
    }

    /// Streaming counterpart of [`Self::reset_with_trace`]: rearm with
    /// an empty, unsealed stream.
    pub fn reset_stream(&mut self, cfg: SimConfig, sched: S) {
        self.cfg = cfg;
        self.sched = sched;
        self.trace.clear();
        self.sealed = false;
        self.events.clear();
        self.seq = DYN_SEQ_BASE;
        self.now = 0.0;
        self.jobs.clear();
        self.job_slot.clear();
        self.groups.clear();
        self.res = SimResult::default();
        self.last_rate_change = 0.0;
        self.cur_rate_per_h = 0.0;
        self.cur_roll_gpus = 0;
        self.cur_train_gpus = 0;
        self.arm_faults();
    }

    fn push(&mut self, t: f64, ev: FEv) {
        self.seq += 1;
        self.events.push(FEvent { t, seq: self.seq, ev });
    }

    // NOTE: the four accounting helpers below (node_busy_add,
    // train_busy_add, integrate_cost, rate_changed) intentionally mirror
    // `engine::Simulator`'s, expression for expression — the cross-tier
    // property tests compare exactly these integrals, so a fix applied
    // to one tier must land in both (divergence fails prop_fluid, it
    // does not pass silently).
    fn node_busy_add(&mut self, gid: usize, node: usize, gpu_s: f64) {
        let v = &mut self.res.roll_node_busy_gpu_s;
        if v.len() <= gid {
            v.resize_with(gid + 1, Vec::new);
        }
        let nv = &mut v[gid];
        if nv.len() <= node {
            nv.resize(node + 1, 0.0);
        }
        nv[node] += gpu_s;
    }

    fn train_busy_add(&mut self, gid: usize, gpu_s: f64) {
        let v = &mut self.res.train_group_busy_gpu_s;
        if v.len() <= gid {
            v.resize(gid + 1, 0.0);
        }
        v[gid] += gpu_s;
    }

    fn integrate_cost(&mut self) {
        let dt_h = (self.now - self.last_rate_change) / 3600.0;
        self.res.cost_usd += dt_h * self.cur_rate_per_h;
        let dt = self.now - self.last_rate_change;
        self.res.roll_prov_gpu_s += dt * self.cur_roll_gpus as f64;
        self.res.train_prov_gpu_s += dt * self.cur_train_gpus as f64;
        self.last_rate_change = self.now;
    }

    fn rate_changed(&mut self) {
        self.integrate_cost();
        self.cur_rate_per_h = self.sched.cost_per_hour();
        let (r, t) = self.sched.gpus();
        self.cur_roll_gpus = r;
        self.cur_train_gpus = t;
        self.res.peak_roll_gpus = self.res.peak_roll_gpus.max(r);
        self.res.peak_train_gpus = self.res.peak_train_gpus.max(t);
        self.res.usage_curve.push((self.now, r, t));
    }

    /// Run to completion, returning the results.
    pub fn run(mut self) -> SimResult {
        self.run_to_end()
    }

    pub fn run_to_end(&mut self) -> SimResult {
        self.seal();
        while let Some(e) = self.events.pop() {
            self.step(e);
        }
        self.integrate_cost();
        self.res.makespan_s = self.now;
        self.res.avg_cost_per_hour = if self.now > 0.0 {
            self.res.cost_usd / (self.now / 3600.0)
        } else {
            0.0
        };
        std::mem::take(&mut self.res)
    }

    /// One event through the guards and the dispatch — shared by the
    /// batch drain ([`Self::run_to_end`]) and the incremental
    /// [`Self::advance_to`].
    fn step(&mut self, e: FEvent) {
        // Fault events outliving the workload are inert; don't let them
        // advance the clock past the last completion. An unsealed stream
        // may still feed more jobs, so the guard only arms once sealed —
        // exactly matching the batch run, where the full trace length is
        // known from the start.
        if matches!(e.ev, FEv::Fault(_))
            && self.sealed
            && self.res.outcomes.len() == self.trace.total()
        {
            return;
        }
        // A superseded rejoin (its victim was re-suspended before it
        // fired) can outlive the workload; it must not advance the
        // clock. Fault-free Joins are never stale (epoch 0, the job
        // cannot finish before joining), so fault-free runs stay
        // bit-identical.
        if let FEv::Join(slot, ep) = e.ev {
            if self.jobs[slot].finished || self.jobs[slot].epoch != ep {
                return;
            }
        }
        debug_assert!(e.t >= self.now - 1e-9, "time went backwards");
        self.now = e.t;
        self.res.events_processed += 1;
        match e.ev {
            FEv::Arrival(i) => self.on_arrival(i),
            FEv::Join(slot, ep) => self.on_join(slot, ep),
            FEv::Recheck(gid, ver) => self.on_recheck(gid, ver),
            FEv::Fault(idx) => self.on_fault(idx),
        }
    }

    fn ensure_group(&mut self, gid: usize) {
        if self.groups.len() <= gid {
            self.groups.resize_with(gid + 1, FluidGroup::default);
        }
    }

    fn on_arrival(&mut self, idx: usize) {
        let spec = self.trace.take(idx).expect("arrival fires once per job");
        let id = spec.id;
        let d = self.sched.place(spec.clone());
        self.rate_changed();

        let group = self.sched.group(d.group_id).expect("placed group exists");
        let gj = group.jobs().iter().find(|j| j.spec.id == id).expect("job in group");
        let solo_est_iter_s = gj.t_solo();
        let train_gpus = group.train_gpus();
        let train_scale = if matches!(spec.phases, PhaseSpec::Direct { .. }) {
            1.0
        } else {
            spec.n_train_gpus as f64 / train_gpus as f64
        };
        let t_sync = sync_time_s(
            self.cfg.sync_scheme,
            spec.model_bytes(),
            train_gpus,
            spec.n_roll_gpus,
        );
        let pool = crate::cluster::node::PoolKind::Rollout;
        let cold = self.cfg.switch.cold_s(spec.params_b, pool);
        let (warm_roll, warm_train) = if self.cfg.warm_starts {
            (
                self.cfg.switch.warm_s(spec.params_b, pool),
                self.cfg.switch.warm_s(spec.params_b, crate::cluster::node::PoolKind::Train),
            )
        } else {
            (
                self.cfg.switch.cold_s(spec.params_b, pool),
                self.cfg.switch.cold_s(spec.params_b, crate::cluster::node::PoolKind::Train),
            )
        };

        // Replay the exact engine's per-job PRNG stream: one sample plus
        // the two tail-shape forks per iteration, in the engine's order.
        // The resulting per-iteration means (and solo_actual_s, which is
        // accumulated with the engine's exact expression order) are
        // bit-identical to the exact tier's realized values.
        let n_eff = spec.n_iters.max(1);
        let mut root = Rng::new(self.cfg.seed ^ (id as u64).wrapping_mul(0x9E37_79B9));
        let mut rng = root.fork(1);
        let mut sum_roll = 0.0;
        let mut sum_train = 0.0;
        let mut sum_train_raw = 0.0;
        let mut solo = 0.0;
        for it in 0..n_eff {
            let s = spec.sample_iter_with(&self.cfg.model, &mut rng, &mut self.scratch_lengths);
            let tt = s.t_train * train_scale;
            sum_roll += s.t_roll;
            sum_train += tt;
            sum_train_raw += s.t_train;
            solo += s.t_roll + tt + t_sync;
            let _ = rng.fork(it as u64);
            let _ = rng.fork(it as u64 ^ 0xabc);
        }
        let mean_roll = sum_roll / n_eff as f64;
        let mean_train = sum_train / n_eff as f64;
        let occ_roll = warm_roll + mean_roll;
        let occ_train = warm_train + mean_train;

        let slot = self.jobs.len();
        self.jobs.push(FluidJob {
            id,
            gid: d.group_id,
            roll_nodes: d.roll_nodes,
            train_gpus,
            occ_roll,
            occ_train,
            t_sync,
            path: occ_roll + occ_train + t_sync,
            n_eff,
            done_iters: 0.0,
            finished: false,
            epoch: 0,
            params_b: spec.params_b,
            warm_train,
            mean_train_raw: sum_train_raw / n_eff as f64,
            direct: matches!(spec.phases, PhaseSpec::Direct { .. }),
            n_roll_gpus: spec.n_roll_gpus,
            spec_train_gpus: spec.n_train_gpus,
            model_bytes: spec.model_bytes(),
            recoveries: 0,
            recovery_s: 0.0,
            arrival_s: spec.arrival_s,
            slo: spec.slo,
            n_iters_raw: spec.n_iters,
            solo_actual_s: solo,
            solo_est_iter_s,
            init_s: cold,
        });
        self.job_slot.insert(id, slot);

        self.ensure_group(d.group_id);
        // Phase-in wait: half the rollout occupancy other unfinished
        // members already pin on this job's nodes (zero when it shares
        // nothing — an isolated or disjointly-pinned join starts clean).
        let mut shared = 0.0f64;
        {
            let g = &self.groups[d.group_id];
            let me = &self.jobs[slot];
            for &n in &me.roll_nodes {
                let mut load = 0.0;
                for &o in &g.admitted {
                    if o != slot
                        && !self.jobs[o].finished
                        && self.jobs[o].roll_nodes.contains(&n)
                    {
                        load += self.jobs[o].occ_roll;
                    }
                }
                shared = shared.max(load);
            }
        }
        let delay = 0.5 * shared;
        self.groups[d.group_id].admitted.push(slot);
        self.push(self.now + cold + delay, FEv::Join(slot, 0));
    }

    fn on_join(&mut self, slot: usize, epoch: u32) {
        if self.jobs[slot].finished || self.jobs[slot].epoch != epoch {
            return; // superseded by a fault suspension
        }
        let gid = self.jobs[slot].gid;
        self.advance_group(gid);
        let g = &mut self.groups[gid];
        g.members.push(slot);
        g.version += 1;
        self.recompute_period(gid);
        self.schedule_recheck(gid);
    }

    fn on_recheck(&mut self, gid: usize, version: u64) {
        if self.groups[gid].version != version {
            return; // stale prediction
        }
        self.advance_group(gid);
        // Complete everything at (or within fp-epsilon of) its target, in
        // join order — deterministic, mirroring the engine's event order.
        let done: Vec<usize> = self.groups[gid]
            .members
            .iter()
            .copied()
            .filter(|&s| self.jobs[s].remaining() <= EPS_ITERS)
            .collect();
        for &slot in &done {
            self.finish_job(slot);
        }
        let g = &mut self.groups[gid];
        if !done.is_empty() {
            g.members.retain(|s| !done.contains(s));
            g.admitted.retain(|s| !done.contains(s));
        }
        g.version += 1;
        self.recompute_period(gid);
        self.schedule_recheck(gid);
    }

    fn finish_job(&mut self, slot: usize) {
        let (id, outcome) = {
            let j = &mut self.jobs[slot];
            j.finished = true;
            j.done_iters = j.n_eff as f64;
            (
                j.id,
                JobOutcome {
                    arrival_s: j.arrival_s,
                    finish_s: self.now,
                    solo_actual_s: j.solo_actual_s,
                    solo_est_s: j.init_s + j.solo_est_iter_s * j.n_iters_raw as f64,
                    slo: j.slo,
                    iters: j.n_eff,
                    migrations: 0,
                    recoveries: j.recoveries,
                    recovery_s: j.recovery_s,
                },
            )
        };
        self.res.outcomes.insert(id, outcome);
        self.sched.complete(id);
        self.rate_changed();
    }

    /// Advance a group's members from `last_t` to `now` at the current
    /// rate, accruing progress-proportional busy time.
    fn advance_group(&mut self, gid: usize) {
        let dt = self.now - self.groups[gid].last_t;
        self.groups[gid].last_t = self.now;
        if dt <= 0.0 || self.groups[gid].members.is_empty() {
            return;
        }
        let period = self.groups[gid].period;
        if period <= 0.0 || !period.is_finite() {
            return;
        }
        let di = dt / period;
        let n_members = self.groups[gid].members.len();
        for mi in 0..n_members {
            let slot = self.groups[gid].members[mi];
            let (di_j, occ_roll, occ_train, train_gpus, n_pins) = {
                let j = &mut self.jobs[slot];
                let di_j = di.min(j.remaining());
                j.done_iters += di_j;
                (di_j, j.occ_roll, j.occ_train, j.train_gpus, j.roll_nodes.len())
            };
            if di_j <= 0.0 {
                continue;
            }
            self.res.roll_busy_gpu_s += di_j * occ_roll * (n_pins * GPUS_PER_NODE) as f64;
            for pi in 0..n_pins {
                let n = self.jobs[slot].roll_nodes[pi];
                self.node_busy_add(gid, n, di_j * occ_roll * GPUS_PER_NODE as f64);
            }
            self.res.train_busy_gpu_s += di_j * occ_train * train_gpus as f64;
            self.train_busy_add(gid, di_j * occ_train * train_gpus as f64);
        }
    }

    /// Recompute the group's meta-iteration period `P` from its current
    /// rotation (member paths, per-node rollout loads, the serial
    /// training queue).
    fn recompute_period(&mut self, gid: usize) {
        let g = &self.groups[gid];
        let mut period = 0.0f64;
        let mut train_load = 0.0f64;
        self.scratch_node_load.clear();
        for &slot in &g.members {
            let j = &self.jobs[slot];
            period = period.max(j.path);
            train_load += j.occ_train;
            for (i, &n) in j.roll_nodes.iter().enumerate() {
                if j.roll_nodes[..i].contains(&n) {
                    continue; // duplicated pin counts once
                }
                if self.scratch_node_load.len() <= n {
                    self.scratch_node_load.resize(n + 1, 0.0);
                }
                self.scratch_node_load[n] += j.occ_roll;
            }
        }
        for &load in &self.scratch_node_load {
            period = period.max(load);
        }
        self.groups[gid].period = period.max(train_load);
    }

    /// Queue the group's next predicted completion under the current
    /// period (tagged with the version so membership changes void it).
    fn schedule_recheck(&mut self, gid: usize) {
        let g = &self.groups[gid];
        if g.members.is_empty() {
            return;
        }
        let mut rem_min = f64::INFINITY;
        for &slot in &g.members {
            rem_min = rem_min.min(self.jobs[slot].remaining());
        }
        let t = g.last_t + rem_min * g.period;
        let version = g.version;
        self.push(t, FEv::Recheck(gid, version));
    }

    /// Apply the pending fault event, then keep the stream armed while
    /// any job is outstanding (ISSUE 5). `repair_s` is not used here:
    /// the fluid tier treats crashed nodes as hot-spared (see the
    /// module docs' soundness note).
    fn on_fault(&mut self, handle: usize) {
        let fe = self.faults_rt.as_ref().expect("fault event without a stream").event(handle);
        match fe.kind {
            FaultKind::NodeCrash { .. } => self.apply_crash(fe.victim),
            FaultKind::Straggler { factor } => self.apply_straggler(fe.victim, factor),
        }
        if !self.sealed || self.res.outcomes.len() < self.trace.total() {
            if let Some((h, t)) = self.faults_rt.as_mut().and_then(FaultStream::pull) {
                self.push(t.max(self.now), FEv::Fault(h));
            }
        }
    }

    /// Node crash as a piecewise rate change: advance the damaged group
    /// to `now`, roll every victim back to its iteration checkpoint,
    /// heal the group (repin / spill via `coordinator::repair`), and
    /// suspend victims for their recovery delay — the group's period
    /// drops while they are out and rises when they rejoin.
    fn apply_crash(&mut self, victim: u64) {
        let Some((gid, node)) = repair::pick_victim(self.sched.groups(), victim) else {
            return;
        };
        self.res.crashes += 1;
        let Some(out) = self.sched.repair_node_crash(gid, node) else {
            return; // scheduler without repair support: nothing to do here
        };
        self.ensure_group(gid);
        self.advance_group(gid);
        self.rate_changed();
        for fate in &out.fates {
            let jid = fate.job();
            let Some(&slot) = self.job_slot.get(&jid) else { continue };
            if self.jobs[slot].finished {
                continue;
            }
            // Checkpoint rollback first, under the member's OLD rates.
            self.rollback_partial_iter(slot);
            let repinned = matches!(fate, MemberFate::Repinned { .. });
            match fate {
                MemberFate::Repinned { roll_nodes, .. } => {
                    self.jobs[slot].roll_nodes = roll_nodes.clone();
                    self.res.evictions += 1;
                }
                MemberFate::Spilled { decision, .. } => {
                    self.remove_admitted(gid, slot);
                    self.respill(slot, decision);
                    self.res.spills += 1;
                }
            }
            let delay = repair::recovery_delay_s(
                &self.cfg.switch,
                &self.cfg.migration,
                self.jobs[slot].params_b,
                repinned,
            );
            self.res.recovery_time_s += delay;
            let ep = {
                let j = &mut self.jobs[slot];
                j.recoveries += 1;
                j.recovery_s += delay;
                j.epoch = j.epoch.wrapping_add(1);
                j.epoch
            };
            self.groups[gid].members.retain(|&s| s != slot);
            self.push(self.now + delay, FEv::Join(slot, ep));
        }
        let g = &mut self.groups[gid];
        g.version += 1;
        self.recompute_period(gid);
        self.schedule_recheck(gid);
    }

    /// Discard a victim's partial iteration (checkpoints live at
    /// iteration boundaries): the fractional progress becomes wasted
    /// work at the member's current occupancies.
    fn rollback_partial_iter(&mut self, slot: usize) {
        let (frac, waste) = {
            let j = &self.jobs[slot];
            let frac = j.done_iters - j.done_iters.floor();
            let waste = frac
                * (j.occ_roll * (j.roll_nodes.len() * GPUS_PER_NODE) as f64
                    + j.occ_train * j.train_gpus as f64);
            (frac, waste)
        };
        if frac > 0.0 {
            self.jobs[slot].done_iters = self.jobs[slot].done_iters.floor();
            self.res.wasted_gpu_s += waste;
        }
    }

    /// Drop a spilled member from a group's admitted set (it left for
    /// another group; join-delay estimates must stop counting it).
    fn remove_admitted(&mut self, gid: usize, slot: usize) {
        self.groups[gid].admitted.retain(|&s| s != slot);
    }

    /// Move a spilled victim onto its new group's rates: the training
    /// pool follows the placement (DP rescale + sync time re-derived);
    /// the canonical solo replay and SLO reference stay fixed.
    fn respill(&mut self, slot: usize, d: &Decision) {
        let train_gpus = self.sched.group(d.group_id).expect("spill target exists").train_gpus();
        self.ensure_group(d.group_id);
        {
            let j = &mut self.jobs[slot];
            j.gid = d.group_id;
            j.roll_nodes = d.roll_nodes.clone();
            j.train_gpus = train_gpus;
            let scale = if j.direct { 1.0 } else { j.spec_train_gpus as f64 / train_gpus as f64 };
            j.occ_train = j.warm_train + j.mean_train_raw * scale;
            j.t_sync = sync_time_s(
                self.cfg.sync_scheme,
                j.model_bytes,
                train_gpus,
                j.n_roll_gpus,
            );
            j.path = j.occ_roll + j.occ_train + j.t_sync;
        }
        self.groups[d.group_id].admitted.push(slot);
    }

    /// Straggler as a rate change: members pinned to the slow node are
    /// suspended for the slowdown overhead of one rollout (the
    /// data-parallel batch gates on the slow node), charged as busy +
    /// wasted GPU-time; no state is lost.
    fn apply_straggler(&mut self, victim: u64, factor: f64) {
        let Some((gid, node)) = repair::pick_victim(self.sched.groups(), victim) else {
            return;
        };
        if factor <= 1.0 {
            return;
        }
        self.ensure_group(gid);
        self.advance_group(gid);
        let victims: Vec<usize> = self.groups[gid]
            .members
            .iter()
            .copied()
            .filter(|&s| self.jobs[s].roll_nodes.contains(&node))
            .collect();
        if victims.is_empty() {
            return;
        }
        self.res.stragglers += 1;
        for slot in victims {
            let j = &self.jobs[slot];
            let stall = (factor - 1.0) * j.occ_roll;
            let n_pins = j.roll_nodes.len();
            let gpu_s = stall * (n_pins * GPUS_PER_NODE) as f64;
            self.res.roll_busy_gpu_s += gpu_s;
            self.res.wasted_gpu_s += gpu_s;
            for pi in 0..n_pins {
                let n = self.jobs[slot].roll_nodes[pi];
                self.node_busy_add(gid, n, stall * GPUS_PER_NODE as f64);
            }
            let ep = {
                let j = &mut self.jobs[slot];
                j.epoch = j.epoch.wrapping_add(1);
                j.epoch
            };
            self.groups[gid].members.retain(|&s| s != slot);
            self.push(self.now + stall, FEv::Join(slot, ep));
        }
        let g = &mut self.groups[gid];
        g.version += 1;
        self.recompute_period(gid);
        self.schedule_recheck(gid);
    }
}

/// Fluid counterpart of [`super::engine::run_pooled`]: rearm the
/// worker's pooled fluid simulator or construct it on first use.
pub fn run_pooled<S: GroupScheduler>(
    slab: &mut Option<FluidSimulator<S>>,
    cfg: SimConfig,
    sched: S,
    trace: Vec<JobSpec>,
) -> SimResult {
    match slab {
        Some(sim) => sim.reset_with_trace(cfg, sched, trace),
        None => *slab = Some(FluidSimulator::new(cfg, sched, trace)),
    }
    slab.as_mut().expect("slab populated").run_to_end()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::PhaseModel;
    use crate::coordinator::inter::InterGroupScheduler;
    use crate::sim::engine::{run_rollmux, run_sim, Fidelity, Simulator};

    fn direct_job(
        id: JobId,
        t_roll: f64,
        t_train: f64,
        slo: f64,
        iters: usize,
        arrival: f64,
    ) -> JobSpec {
        JobSpec {
            id,
            name: format!("j{id}"),
            arrival_s: arrival,
            n_iters: iters,
            slo,
            n_roll_gpus: 8,
            n_train_gpus: 8,
            params_b: 7.0,
            phases: PhaseSpec::Direct { t_roll, t_train, cv: 0.0 },
        }
    }

    fn fluid_cfg() -> SimConfig {
        SimConfig { fidelity: Fidelity::Fluid, ..Default::default() }
    }

    #[test]
    fn solo_job_matches_exact_closed_form() {
        // One job, one group: the fluid finish time is exactly
        // cold + n x (warm_r + roll + warm_t + train + sync), which is
        // also the exact engine's timeline.
        let mk = || vec![direct_job(0, 100.0, 50.0, 2.0, 5, 0.0)];
        let exact = run_rollmux(SimConfig::default(), mk());
        let fluid = run_rollmux(fluid_cfg(), mk());
        let a = exact.outcomes[&0].finish_s;
        let b = fluid.outcomes[&0].finish_s;
        assert!((a - b).abs() < 1e-6 * a.max(1.0), "exact {a} vs fluid {b}");
        assert_eq!(fluid.outcomes[&0].iters, 5);
        assert!((exact.makespan_s - fluid.makespan_s).abs() < 1e-6 * exact.makespan_s);
        assert!((exact.cost_usd - fluid.cost_usd).abs() < 1e-6 * exact.cost_usd);
    }

    #[test]
    fn solo_actual_is_bitwise_exact_replay() {
        // The RNG replay must reproduce the engine's sampled solo time
        // bit-for-bit — for stochastic Direct specs too.
        let mk = || {
            let mut a = direct_job(0, 120.0, 60.0, 3.0, 12, 0.0);
            let mut b = direct_job(1, 90.0, 70.0, 3.0, 9, 40.0);
            if let PhaseSpec::Direct { ref mut cv, .. } = a.phases {
                *cv = 0.2;
            }
            if let PhaseSpec::Direct { ref mut cv, .. } = b.phases {
                *cv = 0.1;
            }
            vec![a, b]
        };
        let exact = run_rollmux(SimConfig { seed: 5, ..Default::default() }, mk());
        let fluid = run_rollmux(SimConfig { seed: 5, ..fluid_cfg() }, mk());
        for id in [0usize, 1] {
            assert_eq!(
                exact.outcomes[&id].solo_actual_s.to_bits(),
                fluid.outcomes[&id].solo_actual_s.to_bits(),
                "job {id}: replayed RNG stream diverged"
            );
            assert_eq!(
                exact.outcomes[&id].solo_est_s.to_bits(),
                fluid.outcomes[&id].solo_est_s.to_bits()
            );
        }
    }

    #[test]
    fn multiplexed_pair_close_to_exact() {
        let mk = || {
            vec![
                direct_job(0, 100.0, 80.0, 2.0, 40, 0.0),
                direct_job(1, 80.0, 60.0, 2.0, 40, 0.0),
            ]
        };
        let no_mig = |mut c: SimConfig| {
            c.migration.enabled = false;
            c
        };
        let exact = run_rollmux(no_mig(SimConfig::default()), mk());
        let fluid = run_rollmux(no_mig(fluid_cfg()), mk());
        assert_eq!(exact.outcomes.len(), fluid.outcomes.len());
        assert!((exact.slo_attainment() - fluid.slo_attainment()).abs() <= 0.02 + 1e-12);
        let rel = (exact.makespan_s - fluid.makespan_s).abs() / exact.makespan_s;
        assert!(rel < 0.02, "makespan rel err {rel}");
        // Busy integrals are progress-proportional: totals match.
        let rel_busy =
            (exact.roll_busy_gpu_s - fluid.roll_busy_gpu_s).abs() / exact.roll_busy_gpu_s;
        assert!(rel_busy < 0.02, "roll busy rel err {rel_busy}");
    }

    #[test]
    fn run_sim_dispatches_on_fidelity() {
        let mk = || vec![direct_job(0, 60.0, 40.0, 2.0, 3, 0.0)];
        let sched = InterGroupScheduler::new(PhaseModel::default());
        let exact = run_sim(SimConfig::default(), sched, mk());
        let sched = InterGroupScheduler::new(PhaseModel::default());
        let fluid = run_sim(fluid_cfg(), sched, mk());
        // The exact tier replays phase events; the fluid tier replays
        // only arrival/join/recheck events — far fewer.
        assert!(fluid.events_processed < exact.events_processed);
        assert!(fluid.records.is_empty());
        assert_eq!(fluid.outcomes.len(), 1);
    }

    #[test]
    fn fluid_reset_matches_fresh() {
        let mk = || {
            vec![
                direct_job(0, 100.0, 80.0, 2.0, 10, 0.0),
                direct_job(1, 80.0, 60.0, 2.0, 10, 50.0),
            ]
        };
        let fresh =
            FluidSimulator::new(fluid_cfg(), InterGroupScheduler::new(PhaseModel::default()), mk())
                .run();
        let mut sim = FluidSimulator::new(
            fluid_cfg(),
            InterGroupScheduler::new(PhaseModel::default()),
            vec![direct_job(7, 50.0, 30.0, 4.0, 3, 0.0)],
        );
        let _ = sim.run_to_end();
        sim.reset_with_trace(fluid_cfg(), InterGroupScheduler::new(PhaseModel::default()), mk());
        let reused = sim.run_to_end();
        assert_eq!(fresh.makespan_s.to_bits(), reused.makespan_s.to_bits());
        assert_eq!(fresh.cost_usd.to_bits(), reused.cost_usd.to_bits());
        assert_eq!(fresh.events_processed, reused.events_processed);
        for (id, a) in &fresh.outcomes {
            let b = &reused.outcomes[id];
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
        }
    }

    #[test]
    fn fluid_handles_simulator_unsupported_extras_gracefully() {
        // record_gantt on: fluid has no phase events, records stay empty
        // but outcomes are unaffected.
        let mk = || vec![direct_job(0, 60.0, 40.0, 2.0, 4, 0.0)];
        let mut cfg = fluid_cfg();
        cfg.record_gantt = true;
        let a = run_rollmux(cfg, mk());
        let b = run_rollmux(fluid_cfg(), mk());
        assert!(a.records.is_empty());
        assert_eq!(
            a.outcomes[&0].finish_s.to_bits(),
            b.outcomes[&0].finish_s.to_bits()
        );
    }

    /// ISSUE 5: the chaos tier on the fluid path — crashes roll victims
    /// back to iteration checkpoints, suspend them for recovery, and the
    /// accounting shows it (goodput < busy, recovery time > 0) while
    /// every job still completes.
    #[test]
    fn fluid_chaos_recovers_and_accounts() {
        use crate::sim::faults::FaultConfig;
        let mk = || {
            vec![
                direct_job(0, 100.0, 80.0, 20.0, 30, 0.0),
                direct_job(1, 80.0, 60.0, 20.0, 30, 0.0),
            ]
        };
        let mut c = fluid_cfg();
        c.faults = Some(FaultConfig {
            seed: 2,
            mtbf_s: 400.0,
            mean_repair_s: 120.0,
            straggler_frac: 0.2,
            straggler_factor: 1.5,
            max_events: 60,
        });
        let res = run_rollmux(c, mk());
        assert_eq!(res.outcomes.len(), 2, "faults must not lose jobs");
        for o in res.outcomes.values() {
            assert_eq!(o.iters, 30, "all iterations complete despite chaos");
        }
        assert!(res.crashes > 0, "the stream must fire within the makespan");
        assert!(res.recovery_time_s > 0.0);
        assert!(res.wasted_gpu_s > 0.0, "checkpoint rollback discards work");
        assert!(res.goodput_frac() < 1.0);
        assert!(res.outcomes.values().any(|o| o.recoveries > 0));
        let clean = run_rollmux(fluid_cfg(), mk());
        assert!(
            res.makespan_s > clean.makespan_s,
            "chaos {} vs clean {}",
            res.makespan_s,
            clean.makespan_s
        );
        assert_eq!(clean.crashes, 0);
        assert_eq!(clean.wasted_gpu_s, 0.0);
    }

    /// ISSUE 7: a chunk-streamed run — `feed` interleaved with
    /// `advance_to` — is bitwise identical to loading the whole trace up
    /// front, with and without chaos, and the arrival store holds only
    /// the in-flight window while streaming.
    #[test]
    fn streaming_feed_matches_batch_bitwise() {
        use crate::sim::faults::FaultConfig;
        use crate::workload::trace::FleetTraceGen;
        let fault_cases = [
            None,
            Some(FaultConfig {
                seed: 3,
                mtbf_s: 6.0 * 3600.0,
                mean_repair_s: 600.0,
                straggler_frac: 0.3,
                straggler_factor: 1.4,
                max_events: 30,
            }),
        ];
        for faults in fault_cases {
            let cfg = || SimConfig {
                fidelity: Fidelity::Fluid,
                seed: 9,
                faults: faults.clone(),
                ..Default::default()
            };
            let batch = FluidSimulator::new(
                cfg(),
                InterGroupScheduler::new(PhaseModel::default()),
                FleetTraceGen::new(21, 400, 1.0).collect(),
            )
            .run();

            let mut sim =
                FluidSimulator::open_stream(cfg(), InterGroupScheduler::new(PhaseModel::default()));
            let mut gen = FleetTraceGen::new(21, 400, 1.0).peekable();
            let mut fed = 0usize;
            let mut max_window = 0usize;
            while let Some(spec) = gen.next() {
                sim.feed(spec);
                fed += 1;
                if fed % 64 == 0 {
                    if let Some(next) = gen.peek() {
                        sim.advance_to(next.arrival_s);
                        max_window = max_window.max(sim.stream_window());
                    }
                }
            }
            sim.seal();
            let streamed = sim.run_to_end();

            assert!(
                max_window <= 64,
                "store kept {max_window} specs live — streaming is not incremental"
            );
            let tag = if faults.is_some() { "chaos" } else { "clean" };
            assert_eq!(batch.makespan_s.to_bits(), streamed.makespan_s.to_bits(), "{tag}");
            assert_eq!(batch.cost_usd.to_bits(), streamed.cost_usd.to_bits(), "{tag}");
            assert_eq!(batch.roll_busy_gpu_s.to_bits(), streamed.roll_busy_gpu_s.to_bits(), "{tag}");
            assert_eq!(
                batch.train_busy_gpu_s.to_bits(),
                streamed.train_busy_gpu_s.to_bits(),
                "{tag}"
            );
            assert_eq!(batch.wasted_gpu_s.to_bits(), streamed.wasted_gpu_s.to_bits(), "{tag}");
            assert_eq!(batch.events_processed, streamed.events_processed, "{tag}");
            assert_eq!(batch.crashes, streamed.crashes, "{tag}");
            assert_eq!(batch.stragglers, streamed.stragglers, "{tag}");
            assert_eq!(batch.outcomes.len(), streamed.outcomes.len(), "{tag}");
            for (id, a) in &batch.outcomes {
                let b = &streamed.outcomes[id];
                assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits(), "{tag} job {id}");
                assert_eq!(a.recoveries, b.recoveries, "{tag} job {id}");
            }
            if faults.is_some() {
                assert!(batch.crashes + batch.stragglers > 0, "chaos case must exercise faults");
            }
        }
    }

    #[test]
    fn exact_tier_untouched_by_fluid_module() {
        // Simulator::new always runs exact regardless of cfg.fidelity
        // (the documented contract).
        let mk = || vec![direct_job(0, 60.0, 40.0, 2.0, 4, 0.0)];
        let a = Simulator::new(
            fluid_cfg(),
            InterGroupScheduler::new(PhaseModel::default()),
            mk(),
        )
        .run();
        let b = Simulator::new(
            SimConfig::default(),
            InterGroupScheduler::new(PhaseModel::default()),
            mk(),
        )
        .run();
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.events_processed, b.events_processed);
    }
}
