//! Discrete-event cluster simulator.
//!
//! Replays job traces against a pluggable group scheduler (RollMux's
//! Algorithm 1 or the §7.5 baselines), executing phases with sampled
//! stochastic durations on the groups' node pools, applying warm/cold
//! context switches, hierarchical model sync, and long-tail migration.
//! This is the substrate standing in for the paper's 656-GPU testbed
//! (DESIGN.md §2): every reported metric — provisioning cost, GPU usage,
//! bubbles, SLO attainment — is computed from the event timeline.

//! Two fidelity tiers (ISSUE 4, DESIGN.md §12): the event-exact engine
//! ([`engine::Simulator`], bit-identical across queues/policies) and the
//! fluid fast path ([`fluid::FluidSimulator`], bounded-error closed-form
//! rates for fleet-scale sweeps). [`engine::run_sim`] dispatches on
//! [`engine::Fidelity`].
//!
//! The chaos tier (ISSUE 5, DESIGN.md §13) rides on both: a seeded fault
//! stream ([`faults`]) injects node crashes and stragglers, healed by
//! `coordinator::repair` with checkpoint-aware recovery; with the stream
//! empty both tiers stay bitwise identical to the fault-free engine.

//! The snapshot/fork tier (ISSUE 9, DESIGN.md §17) adds a flight
//! recorder ([`recorder`]) cheap enough to leave on, full-state
//! checkpoints ([`engine::SimSnapshot`]) with a deterministic byte
//! codec, and branch-from-t what-if forks ([`engine::Simulator::fork_at`])
//! that are bitwise identical to from-scratch runs.

pub mod arena;
pub mod calendar;
pub mod engine;
pub mod faults;
pub mod fluid;
pub mod gantt;
pub mod recorder;

pub use engine::{
    run_sim, EventQueueKind, Fidelity, GroupScheduler, PhaseKind, PhaseRecord, SimConfig,
    SimResult, SimSnapshot, Simulator, WorldEvent,
};
pub use faults::{FaultConfig, FaultEvent, FaultKind, FaultTraceGen};
pub use fluid::FluidSimulator;
pub use recorder::{Frame, FlightRecorder};
