//! Flight recorder — a compact, append-only stream of engine decisions
//! and observations (DESIGN.md §17).
//!
//! The recorder subsumes gantt recording (`Frame::Phase` wraps the same
//! [`PhaseRecord`] the gantt path emits) and adds the metric series the
//! daemon's event push exposes: per-group utilization samples at every
//! train completion and per-job SLO-slack samples at every sync. Frames
//! are plain pushes into a `Vec` — cheap enough to leave on — and the
//! stream is part of the deterministic state machine: a restored or
//! replayed run re-records the identical frame sequence (property-tested
//! in `tests/prop_snapshot.rs`).
//!
//! ## Canonical order
//!
//! The group-parallel drain (`Simulator::run_parallel`) collects frames
//! per lane and concatenates batches in gid order within a window, so the
//! raw append order differs from the serial loop's. Both paths therefore
//! finish with [`FlightRecorder::canonical_sort`] — a total order on
//! `(time, frame kind, identifying fields, payload bits)` under which any
//! two frames that compare equal are bit-identical, making the sorted
//! stream (and the sorted `SimResult::records`) identical across serial
//! and parallel execution.

use crate::workload::job::JobId;

use super::engine::{PhaseKind, PhaseRecord, WorldEvent};

/// One recorded frame. `Phase` and `World` wrap the engine's existing
/// record types; `Util` and `SloSlack` are the metric series new to the
/// recorder.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// An executed phase (the gantt stream, recorded independently of
    /// `record_gantt`).
    Phase(PhaseRecord),
    /// An externally observable occurrence (done/crash/straggle/repair).
    World(WorldEvent),
    /// Cumulative busy GPU-seconds of one group's pools, sampled when a
    /// member's train phase completes. Lane-local, so serial and
    /// parallel runs sample identical values.
    Util { t: f64, gid: usize, roll_busy_gpu_s: f64, train_busy_gpu_s: f64 },
    /// A job's SLO slack after finishing iteration `iter` (1-based
    /// count of completed iterations): the seconds of headroom left
    /// before the SLO deadline implied by the estimated solo rate.
    /// Negative = the job is currently violating its SLO.
    SloSlack { t: f64, job: JobId, iter: usize, slack_s: f64 },
    /// Decision provenance (ISSUE 10, armed by
    /// `SimConfig::record_decisions`): the inter-group placement verdict
    /// for one arriving job. `considered` lists every candidate group
    /// the scan visited with its marginal-cost delta (ascending gid;
    /// `f64::INFINITY` = infeasible), `gid` is the chosen group and
    /// `kind_tag` the placement kind (0 = direct pack, 1 = rollout
    /// scale, 2 = isolated provision).
    Placement {
        t: f64,
        job: JobId,
        gid: usize,
        kind_tag: u8,
        marginal_cost: f64,
        considered: Vec<(usize, f64)>,
    },
    /// Decision provenance: one victim's fate after a node crash or a
    /// live group-cap shrink — healed in place (`repinned`,
    /// `to_gid == gid`) or spilled to `to_gid`, with the charged
    /// recovery delay. `node` is the crashed group-local node, or
    /// `usize::MAX` for cap-shrink displacement (no node died).
    Repair {
        t: f64,
        gid: usize,
        node: usize,
        job: JobId,
        to_gid: usize,
        repinned: bool,
        delay_s: f64,
    },
    /// Decision provenance: one intra-group dispatch pick. `kind` is the
    /// started phase (0 = rollout, 1 = train), `policy` the intra-policy
    /// tag (0 = FIFO, 1 = round-robin, 2 = SLO-slack priority) and
    /// `queue_depth` the group's dispatch-queue length after the pick.
    Dispatch { t: f64, gid: usize, job: JobId, kind: u8, policy: u8, queue_depth: usize },
}

impl Frame {
    /// Simulated time of the frame (a phase frame sorts at its start).
    pub fn t(&self) -> f64 {
        match self {
            Frame::Phase(r) => r.start,
            Frame::World(w) => match *w {
                WorldEvent::Done { t, .. }
                | WorldEvent::Crash { t, .. }
                | WorldEvent::Straggle { t, .. }
                | WorldEvent::Repair { t, .. }
                | WorldEvent::NodeUp { t, .. } => t,
            },
            Frame::Util { t, .. }
            | Frame::SloSlack { t, .. }
            | Frame::Placement { t, .. }
            | Frame::Repair { t, .. }
            | Frame::Dispatch { t, .. } => *t,
        }
    }

    fn kind_rank(&self) -> u8 {
        match self {
            Frame::Phase(_) => 0,
            Frame::World(_) => 1,
            Frame::Util { .. } => 2,
            Frame::SloSlack { .. } => 3,
            Frame::Placement { .. } => 4,
            Frame::Repair { .. } => 5,
            Frame::Dispatch { .. } => 6,
        }
    }

    /// Total-order key after `(t, kind_rank)`: identifying fields first,
    /// then payload bits, so frames comparing equal are bit-identical.
    fn tie_key(&self) -> (usize, usize, usize, u8, u64, u64) {
        match self {
            Frame::Phase(r) => phase_tie_key(r),
            Frame::World(w) => match *w {
                WorldEvent::Done { job, .. } => (0, job, 0, 0, 0, 0),
                WorldEvent::Crash { gid, node, .. } => (gid, 0, node, 1, 0, 0),
                WorldEvent::Straggle { gid, node, factor, .. } => {
                    (gid, 0, node, 2, factor.to_bits(), 0)
                }
                WorldEvent::Repair { job, gid, to_gid, repinned, .. } => {
                    (gid, job, to_gid, 3, repinned as u64, 0)
                }
                WorldEvent::NodeUp { gid, node, .. } => (gid, 0, node, 4, 0, 0),
            },
            Frame::Util { gid, roll_busy_gpu_s, train_busy_gpu_s, .. } => {
                (*gid, 0, 0, 0, roll_busy_gpu_s.to_bits(), train_busy_gpu_s.to_bits())
            }
            Frame::SloSlack { job, iter, slack_s, .. } => {
                (0, *job, *iter, 0, slack_s.to_bits(), 0)
            }
            // At most one placement per (t, job), so the key identifies
            // the frame; the payload bits keep equal keys bit-identical.
            Frame::Placement { job, gid, kind_tag, marginal_cost, considered, .. } => {
                (*gid, *job, considered.len(), *kind_tag, marginal_cost.to_bits(), 0)
            }
            Frame::Repair { gid, node, job, to_gid, repinned, delay_s, .. } => {
                (*gid, *job, *to_gid, *repinned as u8, delay_s.to_bits(), *node as u64)
            }
            Frame::Dispatch { gid, job, kind, policy, queue_depth, .. } => {
                (*gid, *job, *queue_depth, (*kind << 4) | *policy, 0, 0)
            }
        }
    }
}

fn phase_tie_key(r: &PhaseRecord) -> (usize, usize, usize, u8, u64, u64) {
    let kind = match r.kind {
        PhaseKind::Init => 0u8,
        PhaseKind::Rollout => 1,
        PhaseKind::Train => 2,
        PhaseKind::Sync => 3,
    };
    // Two phase records agreeing on (start, group, job, iter, kind, end)
    // are the same dispatch decision; roll_nodes is determined by it.
    (r.group, r.job, r.iter, kind, r.end.to_bits(), 0)
}

/// Sort a batch of phase records into the recorder's canonical total
/// order. Applied to `SimResult::records` at finalize on both the serial
/// and the group-parallel path, so the gantt stream no longer depends on
/// how windows were drained.
pub fn canonical_sort_records(records: &mut [PhaseRecord]) {
    records.sort_by(|a, b| {
        a.start
            .total_cmp(&b.start)
            .then_with(|| phase_tie_key(a).cmp(&phase_tie_key(b)))
    });
}

/// Sort a bare frame slice into the recorder's canonical total order —
/// the same order [`FlightRecorder::canonical_sort`] produces. The trace
/// query layer (`obs/`) applies this to frames loaded from an archive,
/// so a daemon archive (append order = fanout drain order) and a batch
/// archive (already canonically sorted at finalize) answer every query
/// identically.
pub fn canonical_sort_frames(frames: &mut [Frame]) {
    frames.sort_by(|a, b| {
        a.t()
            .total_cmp(&b.t())
            .then_with(|| a.kind_rank().cmp(&b.kind_rank()))
            .then_with(|| a.tie_key().cmp(&b.tie_key()))
    });
}

/// The append-only frame stream. `Default` is an empty, disarmed-looking
/// recorder; the engine pushes only when `SimConfig::record_flight` (or
/// the specific emitters' own gates) say so.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FlightRecorder {
    frames: Vec<Frame>,
}

impl FlightRecorder {
    #[inline]
    pub fn push(&mut self, f: Frame) {
        self.frames.push(f);
    }

    pub fn frames(&self) -> &[Frame] {
        &self.frames
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Take the buffered frames, leaving the recorder empty (the
    /// daemon's incremental metrics drain).
    pub fn drain(&mut self) -> Vec<Frame> {
        std::mem::take(&mut self.frames)
    }

    /// Append another recorder's frames (lane merge).
    pub fn append(&mut self, other: &mut FlightRecorder) {
        self.frames.append(&mut other.frames);
    }

    /// Sort into the canonical total order (see module docs). Ties are
    /// only between bit-identical frames, so the result is independent
    /// of the pre-sort (serial vs gid-concatenated parallel) order.
    pub fn canonical_sort(&mut self) {
        canonical_sort_frames(&mut self.frames);
    }

    /// The phase records in the stream (the gantt view of the recorder).
    pub fn phase_records(&self) -> impl Iterator<Item = &PhaseRecord> {
        self.frames.iter().filter_map(|f| match f {
            Frame::Phase(r) => Some(r),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(start: f64, group: usize, job: JobId, kind: PhaseKind) -> PhaseRecord {
        PhaseRecord { job, group, kind, iter: 0, start, end: start + 1.0, roll_nodes: vec![] }
    }

    #[test]
    fn canonical_sort_is_order_insensitive() {
        let frames = vec![
            Frame::Phase(rec(2.0, 1, 7, PhaseKind::Rollout)),
            Frame::Util { t: 2.0, gid: 0, roll_busy_gpu_s: 8.0, train_busy_gpu_s: 4.0 },
            Frame::Phase(rec(1.0, 0, 3, PhaseKind::Train)),
            Frame::SloSlack { t: 2.0, job: 7, iter: 1, slack_s: 5.5 },
            Frame::World(WorldEvent::Done { t: 1.0, job: 3 }),
        ];
        let mut a = FlightRecorder { frames: frames.clone() };
        let mut b = FlightRecorder { frames: frames.into_iter().rev().collect() };
        a.canonical_sort();
        b.canonical_sort();
        assert_eq!(a, b);
        // Time is the primary key; kind rank breaks same-t ties.
        assert_eq!(a.frames[0].t(), 1.0);
        assert!(matches!(a.frames[0], Frame::Phase(_)));
        assert!(matches!(a.frames[1], Frame::World(_)));
        assert!(matches!(a.frames[4], Frame::SloSlack { .. }));
    }

    #[test]
    fn provenance_frames_sort_after_metric_frames_at_equal_t() {
        let frames = vec![
            Frame::Dispatch { t: 1.0, gid: 0, job: 2, kind: 0, policy: 0, queue_depth: 1 },
            Frame::Repair {
                t: 1.0,
                gid: 0,
                node: 1,
                job: 2,
                to_gid: 0,
                repinned: true,
                delay_s: 30.0,
            },
            Frame::Placement {
                t: 1.0,
                job: 2,
                gid: 0,
                kind_tag: 0,
                marginal_cost: 0.5,
                considered: vec![(0, 0.5), (1, f64::INFINITY)],
            },
            Frame::SloSlack { t: 1.0, job: 2, iter: 1, slack_s: 3.0 },
        ];
        let mut a = FlightRecorder { frames: frames.clone() };
        let mut b = FlightRecorder { frames: frames.into_iter().rev().collect() };
        a.canonical_sort();
        b.canonical_sort();
        assert_eq!(a, b);
        assert!(matches!(a.frames()[0], Frame::SloSlack { .. }));
        assert!(matches!(a.frames()[1], Frame::Placement { .. }));
        assert!(matches!(a.frames()[2], Frame::Repair { .. }));
        assert!(matches!(a.frames()[3], Frame::Dispatch { .. }));
    }

    #[test]
    fn drain_empties_and_phase_view_filters() {
        let mut fr = FlightRecorder::default();
        fr.push(Frame::Phase(rec(0.0, 0, 1, PhaseKind::Rollout)));
        fr.push(Frame::Util { t: 1.0, gid: 0, roll_busy_gpu_s: 1.0, train_busy_gpu_s: 0.0 });
        assert_eq!(fr.len(), 2);
        assert_eq!(fr.phase_records().count(), 1);
        let taken = fr.drain();
        assert_eq!(taken.len(), 2);
        assert!(fr.is_empty());
    }
}
