//! A calendar (bucketed ring) event queue tuned for the engine's
//! near-monotone virtual time (DESIGN.md §11).
//!
//! The classic `BinaryHeap` pays O(log n) comparisons *and* a cache-hostile
//! sift on every push/pop. A discrete-event engine's pending set is highly
//! structured: events are inserted at `now + duration` with durations
//! clustered around the phase-time scale, so hashing time into fixed-width
//! windows puts only a handful of events in the window being served.
//!
//! Design (and the invariants the equivalence tests pin):
//!
//! * Windows are a **pure function** of `(origin, width)`:
//!   `edge(w) = origin + w * width` computed fresh — never accumulated —
//!   so filing and serving agree exactly and no event can straddle a
//!   drifting boundary. An event with time `t` belongs to the unique
//!   window `w` with `edge(w) <= t < edge(w+1)` (the float division is
//!   fixed up by direct comparison against the edges).
//! * The ring holds the next `NBUCKETS` windows; events beyond the
//!   horizon wait in `far`, a min-heap on `(t, seq)` (O(log n) push —
//!   a sorted vec would cost O(n) per ascending-arrival push while a
//!   trace loads), and are ringed in as the horizon advances. When the
//!   ring is empty the epoch jumps straight to the first far window
//!   (no O(horizon) spinning across idle gaps).
//! * `pop` scans the current window's bucket for the minimum `(t, seq)`
//!   — the **identical total order** (`f64::total_cmp`, then seq) the
//!   heap-based engine used, so pop sequences are bit-identical
//!   (property-tested against `BinaryHeap` in
//!   `rust/tests/prop_calendar_queue.rs`).
//! * The width self-tunes from the observed mean inter-pop gap (after 64
//!   pops, then every 4096): a deterministic function of the popped
//!   stream, so replays retune identically.

use std::collections::BinaryHeap;

const NBUCKETS: usize = 256;
/// First retune happens early (the construction-time width is a guess).
const FIRST_RETUNE: u64 = 64;
const RETUNE_EVERY: u64 = 4096;
const MIN_WIDTH: f64 = 1e-6;
const MAX_WIDTH: f64 = 1e12;

/// A beyond-horizon entry ordered as a MIN-heap element on `(t, seq)`
/// (reversed comparisons; the payload never participates).
#[derive(Clone, Debug)]
struct FarEv<T>(f64, u64, T);

impl<T> PartialEq for FarEv<T> {
    fn eq(&self, o: &Self) -> bool {
        self.0.total_cmp(&o.0) == std::cmp::Ordering::Equal && self.1 == o.1
    }
}
impl<T> Eq for FarEv<T> {}
impl<T> PartialOrd for FarEv<T> {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl<T> Ord for FarEv<T> {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        o.0.total_cmp(&self.0).then(o.1.cmp(&self.1))
    }
}

/// A group-local pending-event lane (ISSUE 7, DESIGN.md §15): the
/// per-co-execution-group partition of the engine's calendar, drained by
/// a parallel worker between scheduler decision points. Pops the exact
/// same `(t, seq)` total order as [`CalendarQueue`] and the heap engine
/// (it reuses [`FarEv`]'s comparator), so a lane drained in isolation
/// replays its group's serial sub-sequence bit for bit.
///
/// Lanes are small (one group's in-flight phase events), so a plain
/// binary heap beats bucketing here; the global calendar keeps the
/// cross-group ordering.
#[derive(Clone, Debug)]
pub struct LaneQueue<T> {
    heap: BinaryHeap<FarEv<T>>,
}

impl<T> LaneQueue<T> {
    pub fn new() -> Self {
        LaneQueue { heap: BinaryHeap::new() }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Insert with an explicit `(t, seq)` key — inherited events keep
    /// their global key so the lane's order matches the serial pop
    /// order; lane-generated events use the lane's local counter.
    pub fn push(&mut self, t: f64, seq: u64, item: T) {
        debug_assert!(t.is_finite(), "event time must be finite");
        self.heap.push(FarEv(t, seq, item));
    }

    /// The earliest `(t, seq, item)` without removing it (the parallel
    /// drain peeks to stop at window horizons and completion barriers).
    pub fn peek(&self) -> Option<(f64, u64, &T)> {
        self.heap.peek().map(|e| (e.0, e.1, &e.2))
    }

    pub fn pop(&mut self) -> Option<(f64, u64, T)> {
        self.heap.pop().map(|FarEv(t, seq, item)| (t, seq, item))
    }
}

#[derive(Clone, Debug)]
pub struct CalendarQueue<T> {
    /// Ring of buckets; window `w` lives at slot `w % NBUCKETS`.
    buckets: Vec<Vec<(f64, u64, T)>>,
    /// Window edge function anchor: `edge(w) = origin + w * width`.
    origin: f64,
    width: f64,
    /// The window currently being served.
    epoch: u64,
    /// Events at or beyond the ring horizon: min-heap on `(t, seq)`.
    far: BinaryHeap<FarEv<T>>,
    /// Entries currently filed in the ring (len - far.len()).
    ring_len: usize,
    len: usize,
    // Deterministic self-tuning state.
    pops_since: u64,
    gap_sum: f64,
    last_pop_t: f64,
    retune_at: u64,
}

impl<T> CalendarQueue<T> {
    /// A queue serving times `>= start_t`.
    pub fn new(start_t: f64) -> Self {
        CalendarQueue {
            buckets: (0..NBUCKETS).map(|_| Vec::new()).collect(),
            origin: start_t,
            width: 1.0,
            epoch: 0,
            far: BinaryHeap::new(),
            ring_len: 0,
            len: 0,
            pops_since: 0,
            gap_sum: 0.0,
            last_pop_t: start_t,
            retune_at: FIRST_RETUNE,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert an event. `seq` must be unique (it breaks time ties exactly
    /// like the heap engine's monotone sequence number).
    pub fn push(&mut self, t: f64, seq: u64, item: T) {
        debug_assert!(t.is_finite(), "event time must be finite");
        self.len += 1;
        self.file((t, seq, item));
    }

    /// Remove and return the earliest `(t, seq, item)`.
    pub fn pop(&mut self) -> Option<(f64, u64, T)> {
        if self.len == 0 {
            return None;
        }
        if self.pops_since >= self.retune_at {
            self.retune();
        }
        loop {
            // Ring in far entries whose window fell inside the horizon.
            let horizon_edge = self.edge(self.epoch + NBUCKETS as u64);
            while self.far.peek().is_some_and(|e| e.0 < horizon_edge) {
                let FarEv(t, seq, item) = self.far.pop().unwrap();
                self.file_ring((t, seq, item));
            }
            let slot = (self.epoch % NBUCKETS as u64) as usize;
            if !self.buckets[slot].is_empty() {
                let b = &mut self.buckets[slot];
                let mut mi = 0;
                for i in 1..b.len() {
                    if b[i].0.total_cmp(&b[mi].0).then(b[i].1.cmp(&b[mi].1)).is_lt() {
                        mi = i;
                    }
                }
                let e = b.swap_remove(mi);
                self.len -= 1;
                self.ring_len -= 1;
                self.gap_sum += e.0 - self.last_pop_t;
                self.last_pop_t = e.0;
                self.pops_since += 1;
                return Some(e);
            }
            if self.ring_len == 0 {
                // Everything left is beyond the horizon: jump straight to
                // the first far entry's window instead of spinning.
                let t = self.far.peek().expect("len > 0 with empty ring").0;
                self.epoch = self.window_of(t).max(self.epoch + 1);
            } else {
                self.epoch += 1;
            }
        }
    }

    fn edge(&self, w: u64) -> f64 {
        self.origin + (w as f64) * self.width
    }

    /// The unique window `w` with `edge(w) <= t < edge(w+1)`. The float
    /// division lands within one window of the truth; the comparison
    /// loops make the assignment exact (and consistent with serving).
    fn window_of(&self, t: f64) -> u64 {
        debug_assert!(t >= self.origin - 1e-9 * self.width.max(1.0));
        let guess = (t - self.origin).max(0.0) / self.width;
        let mut w = if guess >= u64::MAX as f64 { u64::MAX - 1 } else { guess as u64 };
        while w > 0 && t < self.edge(w) {
            w -= 1;
        }
        while t >= self.edge(w + 1) {
            w += 1;
        }
        w
    }

    fn file(&mut self, e: (f64, u64, T)) {
        if e.0 >= self.edge(self.epoch + NBUCKETS as u64) {
            // Beyond the horizon: O(log n) heap push (the common trace
            // load is ascending arrivals — a sorted vec would memmove
            // the whole list per push).
            self.far.push(FarEv(e.0, e.1, e.2));
        } else {
            self.file_ring(e);
        }
    }

    fn file_ring(&mut self, e: (f64, u64, T)) {
        // A caller pushing at the current virtual time can sit fractionally
        // before the serving window's edge; clamp into the serving window
        // (scan-min still pops it in exact order).
        let w = self.window_of(e.0).max(self.epoch);
        self.buckets[(w % NBUCKETS as u64) as usize].push(e);
        self.ring_len += 1;
    }

    /// Re-anchor the window function at the last popped time and resize
    /// the width toward ~4 events per window, then re-file everything.
    /// Purely a function of the popped history — deterministic.
    fn retune(&mut self) {
        let mean_gap = if self.pops_since > 0 {
            self.gap_sum / self.pops_since as f64
        } else {
            self.width
        };
        let new_width = (mean_gap * 4.0).clamp(MIN_WIDTH, MAX_WIDTH);
        self.pops_since = 0;
        self.gap_sum = 0.0;
        self.retune_at = RETUNE_EVERY;
        let mut entries: Vec<(f64, u64, T)> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            entries.append(b);
        }
        // Heap drain order is arbitrary; filing is order-independent
        // (buckets are min-scanned, the far heap re-orders itself).
        entries.extend(self.far.drain().map(|FarEv(t, seq, item)| (t, seq, item)));
        self.origin = self.last_pop_t;
        self.epoch = 0;
        self.width = new_width;
        self.ring_len = 0;
        for e in entries {
            self.file(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    /// Min-heap reference with the engine's exact (t, seq) total order.
    struct HeapEv(f64, u64);
    impl PartialEq for HeapEv {
        fn eq(&self, o: &Self) -> bool {
            self.0.total_cmp(&o.0) == Ordering::Equal && self.1 == o.1
        }
    }
    impl Eq for HeapEv {}
    impl PartialOrd for HeapEv {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for HeapEv {
        fn cmp(&self, o: &Self) -> Ordering {
            o.0.total_cmp(&self.0).then(o.1.cmp(&self.1))
        }
    }

    fn drain_matches(mut q: CalendarQueue<u64>, mut h: BinaryHeap<HeapEv>) {
        let mut last = f64::NEG_INFINITY;
        while let Some((t, seq, item)) = q.pop() {
            let r = h.pop().expect("heap ran dry first");
            assert_eq!(t.to_bits(), r.0.to_bits(), "time order diverged");
            assert_eq!(seq, r.1, "tie-break order diverged");
            assert_eq!(item, seq, "payload follows its key");
            assert!(t >= last, "time went backwards");
            last = t;
        }
        assert!(h.pop().is_none(), "calendar ran dry first");
        assert!(q.is_empty());
    }

    #[test]
    fn matches_heap_on_monotone_stream() {
        let mut q = CalendarQueue::new(0.0);
        let mut h = BinaryHeap::new();
        let mut rng = Rng::new(41);
        let mut now = 0.0;
        let mut seq = 0u64;
        for _ in 0..5000 {
            // A burst of pushes at now + duration, then one pop.
            for _ in 0..rng.range(1, 4) {
                let t = now + rng.exponential(120.0);
                seq += 1;
                q.push(t, seq, seq);
                h.push(HeapEv(t, seq));
            }
            if let Some((t, s, _)) = q.pop() {
                let r = h.pop().unwrap();
                assert_eq!((t.to_bits(), s), (r.0.to_bits(), r.1));
                now = t;
            }
        }
        drain_matches(q, h);
    }

    #[test]
    fn matches_heap_with_ties_and_spikes() {
        // Simultaneous events (pure seq ties), zero-length phases, and
        // far-future spikes crossing the horizon + retune boundaries.
        let mut q = CalendarQueue::new(0.0);
        let mut h = BinaryHeap::new();
        let mut rng = Rng::new(97);
        let mut now = 0.0;
        let mut seq = 0u64;
        for i in 0..20_000u64 {
            let t = match i % 7 {
                0 => now,                              // zero-duration
                1 => now + rng.uniform(0.0, 1e-3),     // sub-width
                2 => now + rng.exponential(5.0),
                3 => now + rng.exponential(900.0),
                4 => now + 1e7 * rng.f64(),            // beyond horizon
                _ => now + rng.exponential(50.0),
            };
            seq += 1;
            q.push(t, seq, seq);
            h.push(HeapEv(t, seq));
            if rng.chance(0.6) {
                if let Some((t, s, _)) = q.pop() {
                    let r = h.pop().unwrap();
                    assert_eq!((t.to_bits(), s), (r.0.to_bits(), r.1));
                    now = t;
                }
            }
        }
        drain_matches(q, h);
    }

    #[test]
    fn idle_gaps_do_not_spin() {
        // A queue whose events sit eons apart must still drain instantly
        // (the epoch jumps rather than walking empty windows).
        let mut q = CalendarQueue::new(0.0);
        for (i, t) in [0.0, 1e3, 1e6, 1e9, 5e11].iter().enumerate() {
            q.push(*t, i as u64, i as u64);
        }
        let mut got = Vec::new();
        while let Some((t, _, _)) = q.pop() {
            got.push(t);
        }
        assert_eq!(got, vec![0.0, 1e3, 1e6, 1e9, 5e11]);
    }

    #[test]
    fn len_tracks_pushes_and_pops() {
        let mut q = CalendarQueue::new(0.0);
        assert!(q.is_empty());
        for i in 0..100u64 {
            q.push(i as f64 * 0.5, i, i);
        }
        assert_eq!(q.len(), 100);
        for _ in 0..40 {
            q.pop();
        }
        assert_eq!(q.len(), 60);
    }
}
