//! Arena / SoA layouts for the fleet hot path (ISSUE 7, DESIGN.md §15).
//!
//! Three pieces, all allocation-stingy and deterministic:
//!
//! * [`GroupAcct`] — one co-execution group's busy/event accumulators,
//!   pulled out of the global [`crate::sim::SimResult`] so that the
//!   group-parallel engine drain can hand each worker its own slice.
//!   The serial engine writes the SAME per-group entries and
//!   `finalize` folds them in ascending group id, a fixed deterministic
//!   order — which is exactly what makes the serial and parallel loops
//!   produce bit-identical `SimResult`s (the fold replaces the old
//!   chronological global accumulation; every global `f64` is now a
//!   per-group chronological sum combined in gid order).
//! * [`AcctArena`] — the dense gid-indexed slab of `GroupAcct`s with
//!   take/put so a window of parallel draining can move a group's
//!   accumulators into a worker and back without cloning.
//! * [`ArrivalStore`] — an arrival-order job store for streaming traces:
//!   dense indices exactly like the batch `Vec<Option<JobSpec>>`, but
//!   settled front entries are compacted away, so a million-job stream
//!   holds only the in-flight window instead of the whole trace.

use std::collections::VecDeque;

/// Per-group busy/event accumulators (the group's slice of the old
/// global `SimResult` streaming integrals). All writes a group-local
/// event handler performs land here; `Simulator::finalize` folds the
/// arena ascending-gid into the flat result fields.
#[derive(Clone, Debug, Default)]
pub struct GroupAcct {
    /// Rollout-pool busy GPU-seconds contributed by this group.
    pub roll_busy_gpu_s: f64,
    /// Training-pool busy GPU-seconds contributed by this group.
    pub train_busy_gpu_s: f64,
    /// Whether the training accumulator was ever written — preserves the
    /// old `resize`-on-write dimensional semantics of
    /// `SimResult::train_group_busy_gpu_s` (a group whose adds cancel to
    /// exactly 0.0 still occupies a slot).
    pub train_touched: bool,
    /// Busy GPU-seconds per group-local rollout node (`resize`-on-write,
    /// mirroring the old `SimResult::roll_node_busy_gpu_s[gid]`).
    pub node_busy_gpu_s: Vec<f64>,
    /// Group-local events processed (folded into
    /// `SimResult::events_processed`; counts are order-independent).
    pub events: usize,
}

impl GroupAcct {
    /// Streaming per-node rollout busy accumulation (GPU-s).
    #[inline]
    pub fn node_busy_add(&mut self, node: usize, gpu_s: f64) {
        if self.node_busy_gpu_s.len() <= node {
            self.node_busy_gpu_s.resize(node + 1, 0.0);
        }
        self.node_busy_gpu_s[node] += gpu_s;
    }

    /// Streaming training-pool busy accumulation (GPU-s).
    #[inline]
    pub fn train_busy_add(&mut self, gpu_s: f64) {
        self.train_touched = true;
        self.train_busy_gpu_s += gpu_s;
    }
}

/// Dense gid-indexed arena of [`GroupAcct`]s. `get_mut` grows on demand
/// (group ids are handed out dense and monotone — same contract the
/// engine's `group_rt` slab relies on).
#[derive(Clone, Debug, Default)]
pub struct AcctArena {
    accts: Vec<GroupAcct>,
}

impl AcctArena {
    pub fn new() -> Self {
        AcctArena { accts: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.accts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accts.is_empty()
    }

    pub fn clear(&mut self) {
        self.accts.clear();
    }

    fn ensure(&mut self, gid: usize) {
        if self.accts.len() <= gid {
            self.accts.resize_with(gid + 1, GroupAcct::default);
        }
    }

    #[inline]
    pub fn get_mut(&mut self, gid: usize) -> &mut GroupAcct {
        self.ensure(gid);
        &mut self.accts[gid]
    }

    #[inline]
    pub fn get(&self, gid: usize) -> Option<&GroupAcct> {
        self.accts.get(gid)
    }

    /// Move a group's accumulators out (for a parallel-drain worker);
    /// the slot is left defaulted and restored via [`Self::put`].
    pub fn take(&mut self, gid: usize) -> GroupAcct {
        self.ensure(gid);
        std::mem::take(&mut self.accts[gid])
    }

    pub fn put(&mut self, gid: usize, acct: GroupAcct) {
        self.ensure(gid);
        self.accts[gid] = acct;
    }
}

/// Arrival-order store for streaming traces (satellite of ISSUE 7).
///
/// The batch tiers take job specs out of a `Vec<Option<JobSpec>>` by
/// arrival index; a 1M-job stream cannot afford the whole vector, so
/// this keeps the same dense indexing while popping settled (taken)
/// entries off the front. Indices are global (never re-based), so
/// events that carry arrival indices stay valid across compaction.
#[derive(Clone, Debug, Default)]
pub struct ArrivalStore<T> {
    /// Global index of `slots[0]`.
    base: usize,
    slots: VecDeque<Option<T>>,
    total: usize,
    taken: usize,
}

impl<T> ArrivalStore<T> {
    pub fn new() -> Self {
        ArrivalStore { base: 0, slots: VecDeque::new(), total: 0, taken: 0 }
    }

    /// Total entries ever pushed (the streaming analogue of
    /// `trace.len()` — used by the batch tiers' settled-world guards).
    pub fn total(&self) -> usize {
        self.total
    }

    /// Entries pushed but not yet taken.
    pub fn outstanding(&self) -> usize {
        self.total - self.taken
    }

    /// In-memory window size (diagnostics; stays O(in-flight jobs)).
    pub fn window_len(&self) -> usize {
        self.slots.len()
    }

    pub fn clear(&mut self) {
        self.base = 0;
        self.slots.clear();
        self.total = 0;
        self.taken = 0;
    }

    /// Append the next arrival; returns its dense global index.
    pub fn push(&mut self, item: T) -> usize {
        let idx = self.total;
        self.slots.push_back(Some(item));
        self.total += 1;
        idx
    }

    /// Take the entry at global index `idx` (once), then compact settled
    /// front entries. Returns `None` if already taken or out of range.
    pub fn take(&mut self, idx: usize) -> Option<T> {
        let off = idx.checked_sub(self.base)?;
        let item = self.slots.get_mut(off)?.take();
        if item.is_some() {
            self.taken += 1;
            while matches!(self.slots.front(), Some(None)) {
                self.slots.pop_front();
                self.base += 1;
            }
        }
        item
    }

    /// Peek the entry at global index `idx` (not yet taken).
    pub fn get(&self, idx: usize) -> Option<&T> {
        let off = idx.checked_sub(self.base)?;
        self.slots.get(off)?.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acct_arena_take_put_roundtrip() {
        let mut a = AcctArena::new();
        a.get_mut(2).roll_busy_gpu_s = 7.0;
        a.get_mut(2).node_busy_add(1, 3.0);
        a.get_mut(0).train_busy_add(5.0);
        let taken = a.take(2);
        assert_eq!(taken.roll_busy_gpu_s, 7.0);
        assert_eq!(taken.node_busy_gpu_s, vec![0.0, 3.0]);
        // The slot is defaulted while taken.
        assert_eq!(a.get(2).unwrap().roll_busy_gpu_s, 0.0);
        a.put(2, taken);
        assert_eq!(a.get(2).unwrap().node_busy_gpu_s.len(), 2);
        assert!(a.get(0).unwrap().train_touched);
        assert!(!a.get(1).unwrap().train_touched);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn acct_preserves_resize_on_write_semantics() {
        let mut a = AcctArena::new();
        // A zero-valued write still marks the slot (old engine resized
        // the flat vectors on every add, value notwithstanding).
        a.get_mut(1).train_busy_add(0.0);
        assert!(a.get(1).unwrap().train_touched);
        a.get_mut(1).node_busy_add(3, 0.0);
        assert_eq!(a.get(1).unwrap().node_busy_gpu_s.len(), 4);
    }

    #[test]
    fn arrival_store_dense_indices_and_compaction() {
        let mut s = ArrivalStore::new();
        for i in 0..10 {
            assert_eq!(s.push(i * 100), i);
        }
        assert_eq!(s.total(), 10);
        assert_eq!(s.outstanding(), 10);
        // Take out of order: middle first, then the front run compacts.
        assert_eq!(s.take(3), Some(300));
        assert_eq!(s.window_len(), 10, "front not settled yet");
        assert_eq!(s.take(0), Some(0));
        assert_eq!(s.take(1), Some(100));
        assert_eq!(s.take(2), Some(200));
        // 0..=3 settled: the window slides past them.
        assert_eq!(s.window_len(), 6);
        assert_eq!(s.take(3), None, "double take");
        assert_eq!(s.take(0), None, "compacted away");
        assert_eq!(s.get(4), Some(&400));
        assert_eq!(s.get(2), None);
        for i in 4..10 {
            assert_eq!(s.take(i), Some(i * 100));
        }
        assert_eq!(s.outstanding(), 0);
        assert_eq!(s.window_len(), 0);
        // Indices keep growing densely after compaction.
        assert_eq!(s.push(999), 10);
        assert_eq!(s.get(10), Some(&999));
    }
}
