//! The event engine.
//!
//! Resources are whole nodes: each group's rollout nodes are individually
//! tracked (jobs pin to subsets), the training pool is a single serial
//! resource (the DP group spans it — paper footnote 2). Phases wait in
//! per-group queues (the runtime-hook-driven queues of §5.1) owned by the
//! shared orchestration core ([`GroupOrchestrator`], DESIGN.md §10): the
//! engine feeds it enqueue/release calls from the virtual-time event loop
//! and the core's [`IntraPolicyKind`] decides dispatch order. With the
//! default `WorkConservingFifo` policy the dispatch is bit-identical to
//! the historical in-engine FIFO scan (gated by
//! `rust/tests/sim_seed_equivalence.rs`).
//!
//! Hot-path layout (EXPERIMENTS.md §Perf): job runtime state lives in a
//! dense slab (`Vec<JobRt>`, slots assigned in arrival order, never
//! reused) and events carry slot indices, so per-event bookkeeping is
//! plain indexed loads instead of `HashMap` probes. Per-group node
//! occupancy is a dense `Vec<Option<slot>>` inside the orchestrator, and
//! the phase queue is a true FIFO `VecDeque`: entries are enqueued at
//! non-decreasing (time, seq), so insertion order IS the old sorted order
//! and the per-dispatch sort the seed engine paid is dropped entirely.
//!
//! ISSUE 3 (DESIGN.md §11): the pending-event set itself is a bucketed
//! [`CalendarQueue`] by default — O(1)-ish push/pop for the engine's
//! near-monotone virtual time — with the historical `BinaryHeap` kept
//! behind [`EventQueueKind::BinaryHeap`] as the equivalence oracle
//! (`rust/tests/prop_calendar_queue.rs` proves bit-identical
//! `SimResult`s). Busy time is accumulated *streaming*, per
//! (group, rollout node) and per group training pool, as phases start —
//! so utilization/bubble accounting no longer needs the `record_gantt`
//! timeline, and `record_gantt: false` sweeps allocate nothing per phase.
//!
//! ISSUE 7 (DESIGN.md §15): the engine loop is two-level. Group-local
//! events (non-final phase completions, tail checks, recoveries) touch
//! only their co-execution group's slice of state — [`LaneCtx`] makes
//! that isolation structural, and both the classic serial loop and the
//! group-parallel [`Simulator::run_parallel`] drain route every such
//! event through the SAME handler code. Global events (arrivals, faults,
//! repairs, final syncs) are window barriers: between consecutive
//! barriers, independent groups advance in parallel worker threads, and
//! the per-group busy accumulators ([`super::arena::GroupAcct`]) fold in
//! ascending group id at `finalize` — a fixed order shared by both
//! loops, which is what keeps `run_parallel` **bit-identical** to
//! `run_to_end` (property-tested in
//! `rust/tests/prop_shard_equivalence.rs`).

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

use super::arena::{AcctArena, GroupAcct};
use super::calendar::{CalendarQueue, LaneQueue};
use super::faults::{FaultConfig, FaultEvent, FaultKind, FaultStream};
use super::recorder::{canonical_sort_records, FlightRecorder, Frame};

use crate::cluster::node::GPUS_PER_NODE;
use crate::cluster::{GpuKind, PhaseModel};
use crate::coordinator::group::Group;
use crate::coordinator::inter::{
    Decision, InterGroupScheduler, PlacementKind, PlacementProvenance, SchedSnapshot,
};
use crate::coordinator::migration::MigrationPolicy;
use crate::coordinator::orchestrator::{CorePhase, GroupOrchestrator, IntraPolicyKind, OrchSnapshot};
use crate::coordinator::repair::{self, MemberFate, RepairOutcome, ShrinkOutcome};
use crate::memory::switching::SwitchModel;
use crate::sync::{sync_time_s, SyncScheme};
use crate::util::rng::Rng;
use crate::workload::job::{JobId, JobSpec, PhaseSpec};

/// Pluggable placement policy: RollMux's inter-group scheduler or one of
/// the baselines (Random / Greedy / offline-optimal assignments).
pub trait GroupScheduler {
    fn place(&mut self, spec: JobSpec) -> Decision;
    fn complete(&mut self, job: JobId);
    fn groups(&self) -> &[Group];
    /// Current burn rate, $/h.
    fn cost_per_hour(&self) -> f64;
    /// Provisioned (rollout, train) GPUs.
    fn gpus(&self) -> (usize, usize);
    /// Look up a live group by id. The default scans; implementations
    /// with an index override it (the engine resolves every arrival's
    /// placed group through this — at fleet scale the default scan is
    /// O(live groups) per arrival, ISSUE 4).
    fn group(&self, gid: usize) -> Option<&Group> {
        self.groups().iter().find(|g| g.id == gid)
    }
    /// Heal a group around a crashed rollout node (ISSUE 5). The default
    /// reports "no repair support": the fault layer then only holds the
    /// node down until its repair completes (baselines don't replan).
    /// `InterGroupScheduler` overrides with full elastic repair
    /// (`coordinator::repair`).
    fn repair_node_crash(&mut self, _gid: usize, _node: usize) -> Option<RepairOutcome> {
        None
    }
    /// Live group-cap reconfiguration (ISSUE 8). The default reports "no
    /// cap support" (`None`): baselines without a residency cap ignore
    /// the reconfig. `InterGroupScheduler` overrides with the trim/spill
    /// surgery (`set_group_cap`).
    fn set_group_cap(&mut self, _cap: Option<usize>) -> Option<Vec<ShrinkOutcome>> {
        None
    }
    /// Arm placement-provenance capture (ISSUE 10). The default ignores
    /// the request — baselines record no provenance and
    /// [`GroupScheduler::take_placement_provenance`] stays `None`.
    fn set_record_provenance(&mut self, _on: bool) {}
    /// Take the provenance captured by the most recent placement scan
    /// (None when unarmed, unsupported, or already consumed).
    fn take_placement_provenance(&mut self) -> Option<PlacementProvenance> {
        None
    }
}

impl GroupScheduler for InterGroupScheduler {
    fn place(&mut self, spec: JobSpec) -> Decision {
        self.schedule(spec)
    }
    fn complete(&mut self, job: JobId) {
        self.complete_job(job)
    }
    fn groups(&self) -> &[Group] {
        &self.groups
    }
    fn cost_per_hour(&self) -> f64 {
        self.total_cost_per_hour()
    }
    fn gpus(&self) -> (usize, usize) {
        self.gpus_in_use()
    }
    fn group(&self, gid: usize) -> Option<&Group> {
        self.group_by_id(gid)
    }
    fn repair_node_crash(&mut self, gid: usize, node: usize) -> Option<RepairOutcome> {
        InterGroupScheduler::repair_node_crash(self, gid, node)
    }
    fn set_group_cap(&mut self, cap: Option<usize>) -> Option<Vec<ShrinkOutcome>> {
        Some(InterGroupScheduler::set_group_cap(self, cap))
    }
    fn set_record_provenance(&mut self, on: bool) {
        InterGroupScheduler::set_record_provenance(self, on)
    }
    fn take_placement_provenance(&mut self) -> Option<PlacementProvenance> {
        InterGroupScheduler::take_placement_provenance(self)
    }
}

/// Boxed schedulers are schedulers too, so heterogeneous sweep drivers
/// can reuse one `Simulator<Box<dyn GroupScheduler>>`'s slabs across
/// policies via [`Simulator::reset_with_trace`] (ISSUE 4).
impl<S: GroupScheduler + ?Sized> GroupScheduler for Box<S> {
    fn place(&mut self, spec: JobSpec) -> Decision {
        (**self).place(spec)
    }
    fn complete(&mut self, job: JobId) {
        (**self).complete(job)
    }
    fn groups(&self) -> &[Group] {
        (**self).groups()
    }
    fn cost_per_hour(&self) -> f64 {
        (**self).cost_per_hour()
    }
    fn gpus(&self) -> (usize, usize) {
        (**self).gpus()
    }
    fn group(&self, gid: usize) -> Option<&Group> {
        (**self).group(gid)
    }
    fn repair_node_crash(&mut self, gid: usize, node: usize) -> Option<RepairOutcome> {
        (**self).repair_node_crash(gid, node)
    }
    fn set_group_cap(&mut self, cap: Option<usize>) -> Option<Vec<ShrinkOutcome>> {
        (**self).set_group_cap(cap)
    }
    fn set_record_provenance(&mut self, on: bool) {
        (**self).set_record_provenance(on)
    }
    fn take_placement_provenance(&mut self) -> Option<PlacementProvenance> {
        (**self).take_placement_provenance()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    Init,
    Rollout,
    Train,
    Sync,
}

/// One executed phase, for gantt/metrics export.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseRecord {
    pub job: JobId,
    pub group: usize,
    pub kind: PhaseKind,
    pub iter: usize,
    pub start: f64,
    pub end: f64,
    /// (group-local rollout nodes) — empty for train/sync records.
    pub roll_nodes: Vec<usize>,
}

/// Which pending-event structure the engine runs on. Pop order is a total
/// order on `(time, seq)` either way, so results are bit-identical
/// (property-tested); the calendar queue is the fast default.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EventQueueKind {
    /// Bucketed calendar ring tuned for near-monotone time (DESIGN.md §11).
    #[default]
    Calendar,
    /// The historical binary heap — kept as the equivalence oracle and
    /// bench baseline.
    BinaryHeap,
}

/// Which simulation tier runs a trace (DESIGN.md §12).
///
/// * `Exact` — the event-exact discrete-event engine ([`Simulator`]),
///   bit-identical across queues/policies (the PR 1-3 oracle discipline).
/// * `Fluid` — the piecewise-constant-rate fast path
///   ([`crate::sim::fluid::FluidSimulator`]): groups advance by
///   closed-form phase rates between scheduler decision points, skipping
///   intra-cycle events entirely. Bounded-error approximation
///   (property-tested ≤2% on attainment / iters-per-kUSD / bubbles over
///   its soundness domain), built for 100k-job fleet sweeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Fidelity {
    #[default]
    Exact,
    Fluid,
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub seed: u64,
    pub model: PhaseModel,
    pub migration: MigrationPolicy,
    pub switch: SwitchModel,
    /// If false, every phase activation pays a cold start (ablation).
    pub warm_starts: bool,
    pub sync_scheme: SyncScheme,
    /// Intra-group dispatch policy (DESIGN.md §10). The default
    /// `WorkConservingFifo` reproduces the historical engine exactly.
    pub intra: IntraPolicyKind,
    /// Record per-phase gantt entries (disable for big sweeps).
    pub record_gantt: bool,
    /// Arm the flight recorder (ISSUE 9, DESIGN.md §17): a compact
    /// append-only stream of phase records, world events, per-group
    /// utilization samples and per-job SLO-slack samples into
    /// [`SimResult::flight`]. Subsumes gantt recording (a `Frame::Phase`
    /// wraps the same [`PhaseRecord`]) and is cheap enough to leave on:
    /// recording never changes engine decisions, so every other result
    /// field is bitwise identical with it on or off (property-tested in
    /// `rust/tests/prop_snapshot.rs`).
    pub record_flight: bool,
    /// Record decision provenance into the flight stream (ISSUE 10,
    /// DESIGN.md §18): `Frame::Placement` for every arrival's candidate
    /// scan, `Frame::Repair` for every crash/shrink victim fate, and
    /// `Frame::Dispatch` for every intra-group pick. Requires
    /// `record_flight` to be observable (frames land in the same
    /// stream); off = the capture passes never run and every result
    /// field is bitwise identical (property-tested in
    /// `rust/tests/prop_trace.rs`).
    pub record_decisions: bool,
    /// Write the finalized flight stream to this path as an `RMTRC01`
    /// trace archive (ISSUE 10, [`crate::obs::FlightArchive`]) when the
    /// run completes. `None` (the default) writes nothing; I/O errors
    /// warn on stderr rather than poisoning the simulation result.
    pub trace_path: Option<std::path::PathBuf>,
    /// Pending-event structure (bit-identical results either way).
    pub event_queue: EventQueueKind,
    /// Simulation tier: event-exact DES or the fluid fast path. Honored
    /// by [`run_sim`]/[`run_rollmux`]; constructing a [`Simulator`]
    /// directly always runs the exact tier.
    pub fidelity: Fidelity,
    /// The chaos tier (ISSUE 5, DESIGN.md §13): a seeded fault stream
    /// injected into either simulation tier. `None` (the default) and
    /// `Some` with an empty stream are **bitwise identical** to the
    /// fault-free engine (property-tested in
    /// `rust/tests/prop_faults.rs`).
    pub faults: Option<FaultConfig>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            seed: 0,
            model: PhaseModel::default(),
            migration: MigrationPolicy::default(),
            switch: SwitchModel::default(),
            warm_starts: true,
            sync_scheme: SyncScheme::Hierarchical,
            intra: IntraPolicyKind::default(),
            record_gantt: false,
            record_flight: false,
            record_decisions: false,
            trace_path: None,
            event_queue: EventQueueKind::default(),
            fidelity: Fidelity::default(),
            faults: None,
        }
    }
}

/// Per-job final statistics.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub arrival_s: f64,
    pub finish_s: f64,
    /// Accumulated solo time for the same sampled iterations (incl. sync).
    pub solo_actual_s: f64,
    /// *Estimated* solo time — n_iters x the conservative worst-case
    /// iteration (+ one cold init). The paper defines the SLO against this
    /// estimate ("T_k_solo is the estimated iteration time when job k is
    /// running alone", §4.2), which is what makes conservative admission
    /// sound.
    pub solo_est_s: f64,
    pub slo: f64,
    pub iters: usize,
    /// Migration count (long-tail consolidations performed).
    pub migrations: usize,
    /// Crash recoveries this job went through (ISSUE 5): each one is a
    /// checkpoint-aware replay of the in-flight iteration after a cold
    /// restart (and possibly a spill into another group).
    pub recoveries: usize,
    /// Total recovery delay the job paid (cold restarts + consolidation
    /// pauses), seconds.
    pub recovery_s: f64,
}

impl JobOutcome {
    /// Slowdown against the SLO reference (estimated solo).
    pub fn slowdown(&self) -> f64 {
        (self.finish_s - self.arrival_s) / self.solo_est_s.max(1e-9)
    }
    /// Slowdown against the sampled actual solo run (reporting only).
    pub fn slowdown_actual(&self) -> f64 {
        (self.finish_s - self.arrival_s) / self.solo_actual_s.max(1e-9)
    }
    pub fn slo_met(&self) -> bool {
        self.slowdown() <= self.slo * (1.0 + 1e-6)
    }
}

#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub records: Vec<PhaseRecord>,
    pub outcomes: HashMap<JobId, JobOutcome>,
    /// Integrated provisioning cost, $.
    pub cost_usd: f64,
    /// Time-averaged burn rate over the makespan, $/h.
    pub avg_cost_per_hour: f64,
    /// Peak provisioned GPUs.
    pub peak_roll_gpus: usize,
    pub peak_train_gpus: usize,
    /// Busy GPU-seconds per pool (for utilization / bubble accounting).
    pub roll_busy_gpu_s: f64,
    pub train_busy_gpu_s: f64,
    /// Provisioned GPU-seconds per pool.
    pub roll_prov_gpu_s: f64,
    pub train_prov_gpu_s: f64,
    pub makespan_s: f64,
    /// (time, roll_gpus, train_gpus) usage curve.
    pub usage_curve: Vec<(f64, usize, usize)>,
    /// Streaming busy GPU-seconds per (group id, group-local rollout
    /// node), accumulated as phases start — available even with
    /// `record_gantt: false` (no post-run interval reconstruction). A
    /// migrated tail's sub-node fraction is attributed to the job's first
    /// pinned node.
    pub roll_node_busy_gpu_s: Vec<Vec<f64>>,
    /// Streaming busy GPU-seconds per group training pool.
    pub train_group_busy_gpu_s: Vec<f64>,
    /// Events processed by the engine loop (the events/s bench metric).
    pub events_processed: usize,
    /// Chaos-tier accounting (ISSUE 5, all zero without faults):
    /// node-crash events applied.
    pub crashes: usize,
    /// Straggler events that actually slowed at least one rollout.
    pub stragglers: usize,
    /// Members healed in place (repinned + cold-restarted).
    pub evictions: usize,
    /// Members spilled into another group through Algorithm 1.
    pub spills: usize,
    /// Total recovery delay across all victims, seconds.
    pub recovery_time_s: f64,
    /// GPU-seconds of discarded or overhead work: progress of
    /// interrupted phases replayed from the last iteration checkpoint,
    /// plus straggler slowdown overhead. `goodput = busy - wasted`.
    pub wasted_gpu_s: f64,
    /// Jobs withdrawn before completion (ISSUE 6): explicit
    /// [`Simulator::cancel_job`] calls plus admissions rolled back by
    /// [`Simulator::rollback_admission`]. Always zero on batch runs —
    /// only the open-world (daemon) API cancels.
    pub cancelled: usize,
    /// The flight-recorder stream (ISSUE 9, DESIGN.md §17): empty unless
    /// `SimConfig::record_flight` armed it. Canonically sorted at
    /// `finalize` — the same total order whether the run was serial or
    /// group-parallel.
    pub flight: FlightRecorder,
}

impl SimResult {
    pub fn slo_attainment(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 1.0;
        }
        let met = self.outcomes.values().filter(|o| o.slo_met()).count();
        met as f64 / self.outcomes.len() as f64
    }

    /// Idle fraction ("dependency bubbles") per pool.
    pub fn bubble_fracs(&self) -> (f64, f64) {
        let r = 1.0 - self.roll_busy_gpu_s / self.roll_prov_gpu_s.max(1e-9);
        let t = 1.0 - self.train_busy_gpu_s / self.train_prov_gpu_s.max(1e-9);
        (r.clamp(0.0, 1.0), t.clamp(0.0, 1.0))
    }

    /// Iterations completed per dollar (cost-efficiency, Fig. 10's metric).
    pub fn iters_per_kusd(&self) -> f64 {
        let iters: usize = self.outcomes.values().map(|o| o.iters).sum();
        iters as f64 / (self.cost_usd / 1000.0).max(1e-9)
    }

    /// Mean slowdown vs the sampled actual solo run (throughput metric).
    pub fn mean_slowdown(&self) -> f64 {
        let v: Vec<f64> = self.outcomes.values().map(|o| o.slowdown_actual()).collect();
        crate::util::stats::mean(&v)
    }

    /// Mean slowdown vs the SLO reference (estimated solo).
    pub fn mean_slowdown_vs_estimate(&self) -> f64 {
        let v: Vec<f64> = self.outcomes.values().map(|o| o.slowdown()).collect();
        crate::util::stats::mean(&v)
    }

    /// Useful GPU-seconds: busy time minus the work crashes discarded
    /// and stragglers burned (ISSUE 5). Equals busy exactly on
    /// fault-free runs.
    pub fn goodput_gpu_s(&self) -> f64 {
        (self.roll_busy_gpu_s + self.train_busy_gpu_s - self.wasted_gpu_s).max(0.0)
    }

    /// Goodput as a fraction of busy time (1.0 on fault-free runs).
    pub fn goodput_frac(&self) -> f64 {
        let busy = self.roll_busy_gpu_s + self.train_busy_gpu_s;
        if busy <= 0.0 {
            return 1.0;
        }
        (self.goodput_gpu_s() / busy).clamp(0.0, 1.0)
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ev {
    /// Index into the trace (the job has no slot yet).
    Arrival(usize),
    /// Rollout tail consolidated onto `kept` nodes; free the rest.
    /// Carries the job's slab slot and restart epoch.
    TailFree(usize, usize, u32),
    /// (slot, kind, iter, epoch). The epoch stamps the job's restart
    /// generation (ISSUE 5): a crash bumps it, so phase events scheduled
    /// before the interrupt are recognized as stale and dropped. Without
    /// faults the epoch is always 0 and behavior is bit-identical to the
    /// pre-chaos engine.
    PhaseDone(usize, PhaseKind, usize, u32),
    /// Apply the generated fault `events[idx]` (ISSUE 5).
    Fault(usize),
    /// A crashed node's repair completed: (group id, group-local node).
    FaultRecover(usize, usize),
    /// A crash victim's recovery delay elapsed: replay the in-flight
    /// iteration from its last checkpoint. (slot, epoch).
    Recover(usize, u32),
}

/// An externally observable engine occurrence (ISSUE 8 event push).
/// Recorded only when armed via [`Simulator::arm_events`] — the daemon's
/// virtual backend arms at construction and drains via
/// [`Simulator::take_world_events`] after every command; batch runs never
/// arm, so batch/parallel results and allocations stay identical to the
/// pre-push engine.
#[derive(Clone, Debug, PartialEq)]
pub enum WorldEvent {
    /// A job reached its final sync and left the cluster.
    Done { t: f64, job: JobId },
    /// A fault-layer node crash landed on a live group.
    Crash { t: f64, gid: usize, node: usize },
    /// A straggler slowdown landed on a node.
    Straggle { t: f64, gid: usize, node: usize, factor: f64 },
    /// Repair/displacement translated one member fate: healed in place
    /// (`repinned`, `to_gid == gid`) or spilled to `to_gid`. Emitted by
    /// both the crash-repair path and live group-cap shrink.
    Repair { t: f64, job: JobId, gid: usize, to_gid: usize, repinned: bool },
    /// A crashed node's repair window elapsed; the node rejoined its pool.
    NodeUp { t: f64, gid: usize, node: usize },
}

#[derive(Clone, Debug)]
struct Event {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Event {
    fn eq(&self, o: &Self) -> bool {
        self.t.total_cmp(&o.t) == Ordering::Equal && self.seq == o.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Event {
    fn cmp(&self, o: &Self) -> Ordering {
        // min-heap by (time, seq); total_cmp keeps the heap sane even if
        // a NaN duration ever slips in (it sorts last instead of
        // panicking mid-pop).
        o.t.total_cmp(&self.t).then(o.seq.cmp(&self.seq))
    }
}

/// Runtime state of an admitted job (one dense slab entry; slots are
/// assigned in arrival order and never reused, so a slot in a stale event
/// can never alias a different job).
struct JobRt {
    spec: JobSpec,
    group: usize,
    roll_nodes: Vec<usize>,
    /// The group's training GPUs at admission (constant: RollMux never
    /// rescales a group's training pool — paper footnote 2).
    train_gpus: usize,
    /// t_train scale from DP-rescale onto the group pool.
    train_scale: f64,
    t_sync: f64,
    iter: usize,
    solo_s: f64,
    solo_est_iter_s: f64,
    init_s: f64,
    migrations: usize,
    rng: Rng,
    /// Sampled durations of the in-flight iteration.
    cur_troll: f64,
    cur_ttrain: f64,
    /// Nominal end of the in-flight rollout (for migration accounting).
    cur_roll_end: f64,
    /// Consolidation pause to apply when the rollout completes (set when
    /// a migration actually fired).
    tail_penalty: f64,
    /// Sub-node GPU fraction the consolidated tail occupies (from the
    /// armed `MigrationPlan`; consumed by the busy accounting in
    /// `on_tail_free`).
    tail_frac: f64,
    /// Finished: stale events against this slot are ignored.
    done: bool,
    /// Restart generation (ISSUE 5): bumped on every crash interrupt /
    /// straggler re-schedule; events carrying an older epoch are stale.
    epoch: u32,
    /// The resource-holding phase currently executing (None while
    /// queued / in init / in sync) — what a crash must truncate.
    phase: Option<PhaseKind>,
    /// Start time of the executing phase (busy-truncation accounting).
    phase_start_s: f64,
    /// Nominal end of the in-flight train phase (crash truncation).
    cur_train_end: f64,
    /// Whether the current iteration's durations have been sampled —
    /// checkpoint replay re-enqueues WITHOUT resampling, so the replayed
    /// iteration runs the same realized durations (solo accounting
    /// counts it once).
    iter_sampled: bool,
    /// Busy GPU-seconds accrued for the in-flight iteration (reset at
    /// the sync checkpoint): a crash discards the WHOLE iteration, so
    /// everything accrued here — completed phases included — becomes
    /// wasted work, not just the interrupted phase's spent time. Kept
    /// in lockstep with the iteration's contributions to the busy
    /// integrals (tail consolidation and straggler stretches included).
    iter_busy_gpu_s: f64,
    /// The part of `iter_busy_gpu_s` already charged to `wasted_gpu_s`
    /// (straggler stretches are wasted immediately); a crash charges
    /// only the difference so overhead is never double-counted.
    iter_wasted_gpu_s: f64,
    /// The in-flight rollout's tail was consolidated (§4.3): busy was
    /// reshaped by `on_tail_free`, so crash truncation must not apply
    /// the plain full-pin remainder subtraction. Survives the
    /// `tail_penalty` take (the pause window), unlike the penalty field.
    consolidated: bool,
    /// An armed-but-unfired tail consolidation: `(t_check, nodes_kept)`.
    /// Stragglers re-arm it at the stretched trigger (the epoch bump
    /// would otherwise cancel the migration silently); crashes and
    /// phase completion clear it.
    pending_tail: Option<(f64, usize)>,
    /// Chaos accounting mirrored into the JobOutcome.
    recoveries: usize,
    recovery_s: f64,
}

impl JobRt {
    /// Placeholder left in a slab slot while the job is moved into a
    /// [`GroupLane`] for a parallel window. Never dispatched against
    /// (`done: true` would guard it anyway); replaced on lane merge.
    fn tombstone() -> JobRt {
        JobRt {
            spec: JobSpec {
                id: 0,
                name: String::new(),
                arrival_s: 0.0,
                n_iters: 0,
                slo: 0.0,
                n_roll_gpus: 0,
                n_train_gpus: 0,
                params_b: 0.0,
                phases: PhaseSpec::Direct { t_roll: 0.0, t_train: 0.0, cv: 0.0 },
            },
            group: usize::MAX,
            roll_nodes: Vec::new(),
            train_gpus: 0,
            train_scale: 0.0,
            t_sync: 0.0,
            iter: 0,
            solo_s: 0.0,
            solo_est_iter_s: 0.0,
            init_s: 0.0,
            migrations: 0,
            rng: Rng::new(0),
            cur_troll: 0.0,
            cur_ttrain: 0.0,
            cur_roll_end: 0.0,
            tail_penalty: 0.0,
            tail_frac: 0.0,
            done: true,
            epoch: 0,
            phase: None,
            phase_start_s: 0.0,
            cur_train_end: 0.0,
            iter_sampled: false,
            iter_busy_gpu_s: 0.0,
            iter_wasted_gpu_s: 0.0,
            consolidated: false,
            pending_tail: None,
            recoveries: 0,
            recovery_s: 0.0,
        }
    }
}

/// Saved usage-accounting state for a trial admission (ISSUE 6):
/// [`Simulator::usage_mark`] snapshots the peaks and the usage-curve
/// length before a `submit`, and [`Simulator::rollback_admission`]
/// restores them — so an admission the daemon rejects for capacity
/// leaves no transient spike in the final accounting.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionMark {
    peak_roll: usize,
    peak_train: usize,
    curve_len: usize,
}

/// The engine's pending-event set: the calendar ring by default, the
/// historical heap as the oracle. Both pop the exact same `(t, seq)`
/// total order. `Clone` exists for the snapshot layer (DESIGN.md §17):
/// a snapshot drains a clone via `pop_with_seq`, so capture is
/// non-destructive and the serialized order is the pop order.
#[derive(Clone)]
enum EventQueue {
    Calendar(CalendarQueue<Ev>),
    Heap(BinaryHeap<Event>),
}

impl EventQueue {
    fn new(kind: EventQueueKind) -> Self {
        match kind {
            EventQueueKind::Calendar => EventQueue::Calendar(CalendarQueue::new(0.0)),
            EventQueueKind::BinaryHeap => EventQueue::Heap(BinaryHeap::new()),
        }
    }

    fn push(&mut self, t: f64, seq: u64, ev: Ev) {
        match self {
            EventQueue::Calendar(q) => q.push(t, seq, ev),
            EventQueue::Heap(h) => h.push(Event { t, seq, ev }),
        }
    }

    fn pop(&mut self) -> Option<(f64, Ev)> {
        self.pop_with_seq().map(|(t, _, ev)| (t, ev))
    }

    /// Pop with the event's sequence number — the parallel window loop
    /// needs the full `(t, seq)` key to use a barrier as a lane horizon
    /// (and to re-push a deferred barrier under its ORIGINAL key).
    fn pop_with_seq(&mut self) -> Option<(f64, u64, Ev)> {
        match self {
            EventQueue::Calendar(q) => q.pop(),
            EventQueue::Heap(h) => h.pop().map(|e| (e.t, e.seq, e.ev)),
        }
    }

    fn is_empty(&self) -> bool {
        match self {
            EventQueue::Calendar(q) => q.is_empty(),
            EventQueue::Heap(h) => h.is_empty(),
        }
    }

    /// Pop the next event only if it is due at or before `deadline`
    /// (the open-world `step_until` primitive, ISSUE 6). The calendar
    /// ring has no peek, so a beyond-deadline head is popped and pushed
    /// straight back with its original `(t, seq)` — pop order is a
    /// total order on `(t, seq)`, so the re-push cannot reorder
    /// anything.
    fn pop_at_or_before(&mut self, deadline: f64) -> Option<(f64, Ev)> {
        match self {
            EventQueue::Calendar(q) => {
                let (t, seq, ev) = q.pop()?;
                if t > deadline {
                    q.push(t, seq, ev);
                    return None;
                }
                Some((t, ev))
            }
            EventQueue::Heap(h) => {
                match h.peek() {
                    Some(e) if e.t <= deadline => {}
                    _ => return None,
                }
                h.pop().map(|e| (e.t, e.ev))
            }
        }
    }
}

/// Minimum stashed events before a window fans out to the worker pool
/// (ISSUE 7): tiny windows drain inline on the coordinator — through
/// the exact same [`drain_lane`] code, so the threshold cannot change
/// results, only where the work runs.
const PAR_WINDOW_MIN_EVENTS: usize = 96;

/// Where a [`LaneCtx`] finds its job slots: the simulator's dense slab
/// (classic serial loop) or a lane's moved-out `(slot, JobRt)` list
/// (parallel window drain). Owned lookup is a linear scan over the
/// group's members — bounded by the scheduler's max group size.
enum Slots<'a> {
    Slab(&'a mut Vec<JobRt>),
    Owned(&'a mut Vec<(usize, JobRt)>),
}

impl Slots<'_> {
    fn job(&mut self, slot: usize) -> &mut JobRt {
        match self {
            Slots::Slab(v) => &mut v[slot],
            Slots::Owned(v) => {
                let i = v.iter().position(|(s, _)| *s == slot).expect("slot owned by this lane");
                &mut v[i].1
            }
        }
    }

    fn job_ref(&self, slot: usize) -> &JobRt {
        match self {
            Slots::Slab(v) => &v[slot],
            Slots::Owned(v) => {
                let i = v.iter().position(|(s, _)| *s == slot).expect("slot owned by this lane");
                &v[i].1
            }
        }
    }
}

/// Where a [`LaneCtx`] pushes generated events: the global queue
/// (classic loop) or the lane's local queue (parallel drain). Both bump
/// their seq counter per push, preserving the equal-time FIFO order.
enum Sink<'a> {
    Global { events: &'a mut EventQueue, seq: &'a mut u64 },
    Lane { queue: &'a mut LaneQueue<Ev>, seq: &'a mut u64 },
}

impl Sink<'_> {
    fn push(&mut self, t: f64, ev: Ev) {
        match self {
            Sink::Global { events, seq } => {
                **seq += 1;
                events.push(t, **seq, ev);
            }
            Sink::Lane { queue, seq } => {
                **seq += 1;
                queue.push(t, **seq, ev);
            }
        }
    }
}

/// One co-execution group's view of the engine (ISSUE 7, DESIGN.md §15):
/// exactly the state a group-local event handler may touch — its jobs,
/// its orchestration core, its arena accumulators, an event sink and the
/// sampling scratch. The handler bodies moved here VERBATIM from the
/// monolithic `Simulator` impl; the serial loop and the parallel window
/// drain both build a `LaneCtx` and dispatch through it, so there is one
/// copy of the state machine and the parallel path cannot drift.
struct LaneCtx<'a> {
    cfg: &'a SimConfig,
    jobs: Slots<'a>,
    orch: &'a mut GroupOrchestrator,
    acct: &'a mut GroupAcct,
    sink: Sink<'a>,
    now: f64,
    scratch: &'a mut Vec<f64>,
    records: &'a mut Vec<PhaseRecord>,
    flight: &'a mut FlightRecorder,
}

/// Route one phase record to the streams its config gates arm: the gantt
/// vector (`record_gantt`), the flight recorder (`record_flight`), or
/// both. One shared emitter so the serial loop, the lane drain and the
/// coordinator-side recorder cannot drift.
fn emit_phase(
    gantt: bool,
    flight_on: bool,
    records: &mut Vec<PhaseRecord>,
    flight: &mut FlightRecorder,
    rec: PhaseRecord,
) {
    if gantt && flight_on {
        records.push(rec.clone());
        flight.push(Frame::Phase(rec));
    } else if gantt {
        records.push(rec);
    } else if flight_on {
        flight.push(Frame::Phase(rec));
    }
}

impl LaneCtx<'_> {
    /// Route one group-local event through the state machine. Returns
    /// `Some(slot)` when the job's final sync completed — completion
    /// touches the scheduler and the cost integrator (global state), so
    /// the CALLER owns it: the serial loop runs `finish_job`; the
    /// parallel drain stops before final syncs (they are window
    /// barriers) and must never see one here.
    fn dispatch(&mut self, ev: Ev) -> Option<usize> {
        match ev {
            Ev::PhaseDone(slot, kind, iter, ep) => {
                if self.on_phase_done(slot, kind, iter, ep) {
                    return Some(slot);
                }
            }
            Ev::TailFree(slot, kept, ep) => self.on_tail_free(slot, kept, ep),
            Ev::Recover(slot, ep) => self.on_recover(slot, ep),
            Ev::Arrival(_) | Ev::Fault(_) | Ev::FaultRecover(..) => {
                unreachable!("global events never dispatch through a lane view")
            }
        }
        None
    }

    fn sample_iteration(&mut self, slot: usize) {
        let model = &self.cfg.model;
        let rt = self.jobs.job(slot);
        let s = rt.spec.sample_iter_with(model, &mut rt.rng, self.scratch);
        rt.cur_troll = s.t_roll;
        rt.cur_ttrain = s.t_train * rt.train_scale;
        rt.solo_s += s.t_roll + rt.cur_ttrain + rt.t_sync;
        rt.iter_sampled = true;
    }

    fn switch_cost(&self, slot: usize, pool: crate::cluster::node::PoolKind) -> f64 {
        let p = self.jobs.job_ref(slot).spec.params_b;
        if self.cfg.warm_starts {
            self.cfg.switch.warm_s(p, pool)
        } else {
            self.cfg.switch.cold_s(p, pool)
        }
    }

    fn enqueue(&mut self, slot: usize, kind: PhaseKind) {
        let core = match kind {
            PhaseKind::Rollout => CorePhase::Rollout,
            PhaseKind::Train => CorePhase::Train,
            _ => unreachable!("only rollout/train queue"),
        };
        self.orch.enqueue(slot, core);
        self.drain_dispatch();
    }

    /// Drain the group's orchestration core: start every phase the
    /// dispatch policy grants (the core marks resources occupied as it
    /// grants them).
    fn drain_dispatch(&mut self) {
        while let Some(start) = self.orch.next_dispatch() {
            let kind = match start.kind {
                CorePhase::Rollout => PhaseKind::Rollout,
                CorePhase::Train => PhaseKind::Train,
            };
            // Decision provenance (ISSUE 10): one frame per granted
            // dispatch, lane-local — the canonical finalize sort puts
            // serial and parallel streams in the same order.
            if self.cfg.record_flight && self.cfg.record_decisions {
                let rt = self.jobs.job_ref(start.slot);
                self.flight.push(Frame::Dispatch {
                    t: self.now,
                    gid: rt.group,
                    job: rt.spec.id,
                    kind: match kind {
                        PhaseKind::Rollout => 0,
                        _ => 1,
                    },
                    policy: intra_tag(self.cfg.intra) as u8,
                    queue_depth: self.orch.queue_len(),
                });
            }
            self.start_phase(start.slot, kind);
        }
    }

    fn start_phase(&mut self, slot: usize, kind: PhaseKind) {
        let iter = self.jobs.job_ref(slot).iter;
        let ep = self.jobs.job_ref(slot).epoch;
        let now = self.now;
        match kind {
            PhaseKind::Rollout => {
                let warm = self.switch_cost(slot, crate::cluster::node::PoolKind::Rollout);
                let t_roll = self.jobs.job_ref(slot).cur_troll;
                let n_pins = self.jobs.job_ref(slot).roll_nodes.len();
                // (node occupancy was marked by the orchestrator when it
                // granted this dispatch)
                // Long-tail migration (paper §4.3): the plan is prepared
                // here, but whether to consolidate is decided when the
                // threshold is reached — only if another rollout is then
                // actually waiting for these nodes (opportunistic).
                let end = now + warm + t_roll;
                let sample = {
                    let rt = self.jobs.job(slot);
                    let sample = crate::workload::job::IterSample {
                        t_roll,
                        t_train: rt.cur_ttrain,
                        tail_start_frac: {
                            // re-derive the tail from the job's stream so the
                            // plan matches this iteration deterministically
                            rt.rng.fork(iter as u64).uniform(0.55, 0.85)
                        },
                        tail_gpu_frac: rt.rng.fork(iter as u64 ^ 0xabc).uniform(0.1, 0.35),
                    };
                    rt.cur_roll_end = end;
                    rt.phase = Some(PhaseKind::Rollout);
                    rt.phase_start_s = now;
                    rt.consolidated = false;
                    rt.iter_busy_gpu_s += (warm + t_roll) * n_pins as f64 * GPUS_PER_NODE as f64;
                    sample
                };
                if let Some(plan) = self.cfg.migration.plan(&sample, n_pins) {
                    let t_check = now + warm + plan.trigger_at_s;
                    {
                        let rt = self.jobs.job(slot);
                        rt.tail_frac = plan.tail_gpu_frac;
                        rt.pending_tail = Some((t_check, plan.nodes_kept));
                    }
                    self.sink.push(t_check, Ev::TailFree(slot, plan.nodes_kept, ep));
                }
                // Busy accounting assumes no migration; adjusted in
                // on_tail_free when a consolidation actually happens.
                self.acct.roll_busy_gpu_s += (warm + t_roll) * n_pins as f64 * GPUS_PER_NODE as f64;
                for i in 0..n_pins {
                    let n = self.jobs.job_ref(slot).roll_nodes[i];
                    self.acct.node_busy_add(n, (warm + t_roll) * GPUS_PER_NODE as f64);
                }
                self.record_rollout(slot, iter, now, end);
                self.sink.push(end, Ev::PhaseDone(slot, PhaseKind::Rollout, iter, ep));
            }
            PhaseKind::Train => {
                let warm = self.switch_cost(slot, crate::cluster::node::PoolKind::Train);
                let t_train = self.jobs.job_ref(slot).cur_ttrain;
                // (the training pool was marked busy by the orchestrator)
                let end = now + warm + t_train;
                let train_gpus = self.jobs.job_ref(slot).train_gpus;
                self.acct.train_busy_add((warm + t_train) * train_gpus as f64);
                {
                    let rt = self.jobs.job(slot);
                    rt.phase = Some(PhaseKind::Train);
                    rt.phase_start_s = now;
                    rt.cur_train_end = end;
                    rt.iter_busy_gpu_s += (warm + t_train) * train_gpus as f64;
                }
                self.record(slot, PhaseKind::Train, iter, now, end, &[]);
                self.sink.push(end, Ev::PhaseDone(slot, PhaseKind::Train, iter, ep));
            }
            _ => unreachable!(),
        }
    }

    fn on_tail_free(&mut self, slot: usize, kept: usize, epoch: u32) {
        // The rollout hit its completion threshold. Consolidate the tail
        // (paper Fig. 7-bottom) only if another rollout is actually
        // waiting for one of this job's nodes; otherwise let it run out.
        let now = self.now;
        {
            let rt = self.jobs.job_ref(slot);
            if rt.done || rt.epoch != epoch {
                return;
            }
        }
        self.jobs.job(slot).pending_tail = None; // this armed check is consumed
        if self.jobs.job_ref(slot).cur_roll_end <= now {
            return; // phase already over (stale check)
        }
        if !self.orch.has_rollout_waiter_sharing(slot) {
            return;
        }
        let penalty = self.cfg.migration.migrate_cost_s;
        let (remaining, n_pins, tail_frac) = {
            let rt = self.jobs.job(slot);
            rt.tail_penalty = penalty;
            rt.consolidated = true;
            rt.migrations += 1;
            (rt.cur_roll_end - now, rt.roll_nodes.len(), rt.tail_frac)
        };
        // Busy adjustment: freed nodes stop counting; the consolidated
        // tail occupies `kept` nodes plus the plan's sub-node GPU
        // fraction for the remaining time (+ pause). (The seed engine
        // hard-coded 0.25 here instead of the `MigrationPlan`'s computed
        // `tail_gpu_frac` — fixed in ISSUE 2, regression-tested by
        // `tail_busy_accounting_uses_plan_fraction`.)
        let freed = n_pins - kept;
        self.acct.roll_busy_gpu_s -= remaining * freed as f64 * GPUS_PER_NODE as f64;
        self.acct.roll_busy_gpu_s +=
            (remaining + penalty) * (kept as f64 + tail_frac) * GPUS_PER_NODE as f64;
        // Mirror the reshaping into the iteration accrual so a later
        // crash wastes exactly what the busy integrals carry (ISSUE 5).
        {
            let rt = self.jobs.job(slot);
            rt.iter_busy_gpu_s -= remaining * freed as f64 * GPUS_PER_NODE as f64;
            rt.iter_busy_gpu_s +=
                (remaining + penalty) * (kept as f64 + tail_frac) * GPUS_PER_NODE as f64;
        }
        // Mirror the aggregate adjustment into the streaming per-node
        // accumulators: freed nodes stop counting, kept nodes carry the
        // consolidated tail, and the sub-node fraction is attributed to
        // the job's first pinned node.
        for i in 0..n_pins {
            let n = self.jobs.job_ref(slot).roll_nodes[i];
            if i >= kept {
                self.acct.node_busy_add(n, -remaining * GPUS_PER_NODE as f64);
            } else {
                self.acct.node_busy_add(n, (remaining + penalty) * GPUS_PER_NODE as f64);
            }
        }
        let first = self.jobs.job_ref(slot).roll_nodes[0];
        self.acct.node_busy_add(first, (remaining + penalty) * tail_frac * GPUS_PER_NODE as f64);
        self.orch.release_trailing_nodes(slot, kept);
        self.drain_dispatch();
    }

    /// A victim's recovery delay elapsed: replay the in-flight iteration
    /// from its last checkpoint (same sampled durations — solo
    /// accounting counts each sampled iteration once).
    fn on_recover(&mut self, slot: usize, epoch: u32) {
        {
            let rt = self.jobs.job_ref(slot);
            if rt.done || rt.epoch != epoch {
                return;
            }
        }
        if !self.jobs.job_ref(slot).iter_sampled {
            // Crashed during the initial cold load: sample the first
            // iteration now (the recovery delay covered the reload).
            self.sample_iteration(slot);
        }
        self.enqueue(slot, PhaseKind::Rollout);
    }

    /// Returns true when the job's FINAL sync completed (the caller owns
    /// the global completion bookkeeping).
    fn on_phase_done(&mut self, slot: usize, kind: PhaseKind, iter: usize, epoch: u32) -> bool {
        let now = self.now;
        {
            let rt = self.jobs.job_ref(slot);
            if rt.done || rt.epoch != epoch {
                return false;
            }
        }
        match kind {
            PhaseKind::Init => {
                self.sample_iteration(slot);
                self.enqueue(slot, PhaseKind::Rollout);
            }
            PhaseKind::Rollout => {
                // If the tail was consolidated, its completion is delayed
                // by the migration pause (applied exactly once).
                {
                    let rt = self.jobs.job(slot);
                    if rt.tail_penalty > 0.0 {
                        let p = std::mem::take(&mut rt.tail_penalty);
                        rt.cur_roll_end = now + p;
                        let ev = Ev::PhaseDone(slot, PhaseKind::Rollout, iter, epoch);
                        self.sink.push(now + p, ev);
                        return false;
                    }
                    rt.phase = None;
                    rt.pending_tail = None;
                }
                // Release any nodes still held, then queue the train;
                // `enqueue` leaves the group fully drained.
                self.orch.release_rollout(slot);
                self.enqueue(slot, PhaseKind::Train);
            }
            PhaseKind::Train => {
                self.jobs.job(slot).phase = None;
                self.orch.release_train(slot);
                if self.cfg.record_flight {
                    // Utilization sample at every train completion: the
                    // group's CUMULATIVE busy integrals so far. Lane-local
                    // state, so serial and parallel runs sample identical
                    // values at identical times.
                    let gid = self.jobs.job_ref(slot).group;
                    self.flight.push(Frame::Util {
                        t: now,
                        gid,
                        roll_busy_gpu_s: self.acct.roll_busy_gpu_s,
                        train_busy_gpu_s: self.acct.train_busy_gpu_s,
                    });
                }
                // Sync occupies the network, not the pools.
                let t_sync = self.jobs.job_ref(slot).t_sync;
                let end = now + t_sync;
                self.record(slot, PhaseKind::Sync, iter, now, end, &[]);
                self.sink.push(end, Ev::PhaseDone(slot, PhaseKind::Sync, iter, epoch));
                self.drain_dispatch();
            }
            PhaseKind::Sync => {
                let (job, iters_done, finished, slack_s) = {
                    let rt = self.jobs.job(slot);
                    rt.iter += 1;
                    // The sync published the update: the iteration is
                    // checkpointed, nothing accrued so far can be lost.
                    rt.iter_busy_gpu_s = 0.0;
                    rt.iter_wasted_gpu_s = 0.0;
                    // SLO slack after this iteration: the elapsed budget a
                    // pro-rated SLO deadline still allows (negative = the
                    // job is currently violating its SLO).
                    let allowed =
                        rt.spec.slo * (rt.init_s + rt.solo_est_iter_s * rt.iter as f64);
                    let slack = allowed - (now - rt.spec.arrival_s);
                    (rt.spec.id, rt.iter, rt.iter >= rt.spec.n_iters, slack)
                };
                if self.cfg.record_flight {
                    self.flight.push(Frame::SloSlack {
                        t: now,
                        job,
                        iter: iters_done,
                        slack_s,
                    });
                }
                if finished {
                    return true;
                }
                self.sample_iteration(slot);
                self.enqueue(slot, PhaseKind::Rollout);
            }
        }
        false
    }

    fn record(&mut self, slot: usize, kind: PhaseKind, iter: usize, start: f64, end: f64, roll_nodes: &[usize]) {
        if self.cfg.record_gantt || self.cfg.record_flight {
            let rt = self.jobs.job_ref(slot);
            let rec = PhaseRecord {
                job: rt.spec.id,
                group: rt.group,
                kind,
                iter,
                start,
                end,
                roll_nodes: roll_nodes.to_vec(),
            };
            emit_phase(self.cfg.record_gantt, self.cfg.record_flight, self.records, self.flight, rec);
        }
    }

    /// Rollout record: the node list is only cloned when a recording
    /// stream is on (the per-phase allocation the seed engine paid
    /// regardless).
    fn record_rollout(&mut self, slot: usize, iter: usize, start: f64, end: f64) {
        if self.cfg.record_gantt || self.cfg.record_flight {
            let rt = self.jobs.job_ref(slot);
            let rec = PhaseRecord {
                job: rt.spec.id,
                group: rt.group,
                kind: PhaseKind::Rollout,
                iter,
                start,
                end,
                roll_nodes: rt.roll_nodes.clone(),
            };
            emit_phase(self.cfg.record_gantt, self.cfg.record_flight, self.records, self.flight, rec);
        }
    }
}

/// A group's moved-out state for one parallel window (ISSUE 7): the
/// worker drains `queue` against `jobs`/`orch`/`acct` up to (never
/// including) the window's barrier key.
struct GroupLane {
    gid: usize,
    /// `(slab slot, runtime)` for every live member, admission order.
    jobs: Vec<(usize, JobRt)>,
    orch: GroupOrchestrator,
    acct: GroupAcct,
    queue: LaneQueue<Ev>,
    /// Local seq counter for lane-generated events: starts at the global
    /// counter snapshot, which is larger than every inherited seq and
    /// the barrier's — so generated events order after both at equal
    /// times, exactly as the serial loop's fresh seqs would.
    seq: u64,
    /// The window's wall: the global barrier key. Events at or past it
    /// stay queued (leftovers). `None` = drain fully.
    horizon: Option<(f64, u64)>,
    /// Clock high-water of processed events (`NEG_INFINITY` if none).
    now: f64,
    records: Vec<PhaseRecord>,
    /// Lane-local flight-recorder batch, merged (then canonically
    /// sorted at finalize) exactly like `records`.
    flight: FlightRecorder,
    /// Stopped before a would-complete final sync (a global barrier
    /// discovered mid-drain): everything still queued is deferred and
    /// the window's popped barrier must be re-queued behind it.
    hit_completion: bool,
}

impl GroupLane {
    fn job_ref(&self, slot: usize) -> &JobRt {
        let i = self.jobs.iter().position(|(s, _)| *s == slot).expect("slot owned by this lane");
        &self.jobs[i].1
    }
}

/// Drain one lane up to its horizon — the parallel counterpart of the
/// serial loop body, running the SAME `LaneCtx` handlers. Stops early
/// (without popping) at a job's final sync: completions are global.
fn drain_lane(cfg: &SimConfig, lane: &mut GroupLane, scratch: &mut Vec<f64>) {
    loop {
        let Some((t, seq, ev)) = lane.queue.peek() else { break };
        if let Some((bt, bs)) = lane.horizon {
            if t.total_cmp(&bt).then(seq.cmp(&bs)).is_ge() {
                break;
            }
        }
        let ev = *ev;
        if let Ev::PhaseDone(slot, PhaseKind::Sync, iter, ep) = ev {
            let rt = lane.job_ref(slot);
            if !rt.done && rt.epoch == ep && iter + 1 >= rt.spec.n_iters {
                lane.hit_completion = true;
                break;
            }
        }
        lane.queue.pop();
        if let Ev::Recover(slot, ep) = ev {
            // A superseded recovery is pure noise: it must not touch the
            // clock or the event count (mirrors `process_event`'s
            // pre-guard).
            let rt = lane.job_ref(slot);
            if rt.done || rt.epoch != ep {
                continue;
            }
        }
        debug_assert!(t >= lane.now - 1e-9, "lane time went backwards");
        lane.now = t;
        lane.acct.events += 1;
        let mut ctx = LaneCtx {
            cfg,
            jobs: Slots::Owned(&mut lane.jobs),
            orch: &mut lane.orch,
            acct: &mut lane.acct,
            sink: Sink::Lane { queue: &mut lane.queue, seq: &mut lane.seq },
            now: t,
            scratch,
            records: &mut lane.records,
            flight: &mut lane.flight,
        };
        let finished = ctx.dispatch(ev);
        debug_assert!(finished.is_none(), "final syncs stop the lane before dispatch");
    }
}

pub struct Simulator<S: GroupScheduler> {
    pub cfg: SimConfig,
    pub sched: S,
    /// Specs are taken (not cloned) out of the trace on arrival.
    trace: Vec<Option<JobSpec>>,
    events: EventQueue,
    seq: u64,
    now: f64,
    /// Dense job slab, arrival order; never shrinks.
    jobs: Vec<JobRt>,
    /// job id -> slab slot for live lookups (the fault layer resolves
    /// repair outcomes by job id).
    job_slot: HashMap<JobId, usize>,
    /// Armed fault stream (None without `cfg.faults`).
    faults_rt: Option<FaultStream>,
    /// (gid, node) -> latest repair deadline: overlapping crashes of the
    /// same node extend the down window, and only the FaultRecover
    /// matching the latest deadline brings the node back up.
    node_down_until: HashMap<(usize, usize), f64>,
    /// Per-group orchestration core, indexed by group id. REQUIRES dense
    /// ids: every in-tree `GroupScheduler` hands them out monotonically
    /// from 0 (at most one new group per arrival). A scheduler returning
    /// sparse or sentinel ids would make `ensure_group_rt` allocate
    /// `gid + 1` slots.
    group_rt: Vec<GroupOrchestrator>,
    /// Per-group busy/event accumulators (ISSUE 7): every group-local
    /// handler writes its own group's slice; `finalize` folds them into
    /// the flat `SimResult` fields in ascending gid — the same fixed
    /// order whether the run was serial or group-parallel.
    accts: AcctArena,
    /// Live slab slots per group id (admission order) — the move-out
    /// list for parallel windows. Maintained at arrival / spill /
    /// completion / cancellation, all of which are window barriers, so
    /// membership is stable within a window.
    members: Vec<Vec<usize>>,
    /// Max event time processed outside `process_event` (lane drains and
    /// inline stale events in `run_parallel`); `NEG_INFINITY` on serial
    /// runs. `finalize` lifts `now` to it so the makespan and the cost
    /// tail match the serial clock bitwise.
    high_water: f64,
    res: SimResult,
    /// Open-world mode (ISSUE 6): the simulator is a live "virtual
    /// cluster" fed by [`Self::submit`]/[`Self::step_until`] instead of
    /// a pre-loaded trace. The only behavioral difference is that the
    /// chaos stream keeps firing on an idle cluster (a daemon's nodes
    /// fail whether or not jobs are running); batch runs drop
    /// fault-chain events once every job is accounted for, exactly as
    /// before. [`Self::run_to_end`] always closes the world first, so
    /// batch results are bit-identical with or without this flag ever
    /// having been set.
    open_world: bool,
    /// Cost integration state.
    last_rate_change: f64,
    cur_rate_per_h: f64,
    cur_roll_gpus: usize,
    cur_train_gpus: usize,
    /// Reusable Roofline length-batch buffer: the per-iteration
    /// `Vec<f64>` allocation `sample_iter` used to pay is gone (ISSUE 4).
    scratch_lengths: Vec<f64>,
    /// Record [`WorldEvent`]s for the push channel (ISSUE 8). Off by
    /// default; only the daemon's virtual backend arms it.
    emit_events: bool,
    /// Recorded events since the last [`Self::take_world_events`] drain.
    world_events: Vec<WorldEvent>,
}

impl<S: GroupScheduler> Simulator<S> {
    pub fn new(cfg: SimConfig, sched: S, trace: Vec<JobSpec>) -> Self {
        let events = EventQueue::new(cfg.event_queue);
        let mut sim = Simulator {
            cfg,
            sched,
            trace: Vec::new(),
            events,
            seq: 0,
            now: 0.0,
            jobs: Vec::new(),
            job_slot: HashMap::new(),
            faults_rt: None,
            node_down_until: HashMap::new(),
            group_rt: Vec::new(),
            accts: AcctArena::new(),
            members: Vec::new(),
            high_water: f64::NEG_INFINITY,
            res: SimResult::default(),
            open_world: false,
            last_rate_change: 0.0,
            cur_rate_per_h: 0.0,
            cur_roll_gpus: 0,
            cur_train_gpus: 0,
            scratch_lengths: Vec::new(),
            emit_events: false,
            world_events: Vec::new(),
        };
        sim.load_trace(trace);
        // Provenance capture (ISSUE 10) follows the config: armed here
        // and at every rearm/restore so the scheduler's recording state
        // is a pure function of `cfg.record_decisions`.
        let arm = sim.cfg.record_flight && sim.cfg.record_decisions;
        sim.sched.set_record_provenance(arm);
        sim
    }

    fn load_trace(&mut self, trace: Vec<JobSpec>) {
        self.trace.clear();
        self.trace.extend(trace.into_iter().map(Some));
        for i in 0..self.trace.len() {
            let t = self.trace[i].as_ref().expect("fresh trace").arrival_s;
            self.push(t, Ev::Arrival(i));
        }
        self.job_slot.clear();
        self.node_down_until.clear();
        // Arm the chaos stream: one fault event is kept in flight at a
        // time; each application pulls the next (so the stream length
        // adapts to the realized makespan).
        self.faults_rt = FaultStream::arm(self.cfg.faults.as_ref());
        if let Some((h, t)) = self.faults_rt.as_mut().and_then(FaultStream::pull) {
            self.push(t, Ev::Fault(h));
        }
    }

    /// Rearm the simulator for another run, reusing its slabs (the job
    /// slab, trace slab, orchestrator vector and sampling scratch keep
    /// their capacity). Sweep drivers call this between points instead of
    /// reconstructing a `Simulator` per point; the subsequent
    /// [`Self::run_to_end`] is **bit-identical** to a fresh
    /// `Simulator::new(cfg, sched, trace).run()` — every piece of
    /// run-visible state is reset (the event queue is rebuilt so its
    /// deterministic width-retune state starts fresh too). Property-
    /// tested in `rust/tests/prop_fluid.rs`.
    pub fn reset_with_trace(&mut self, cfg: SimConfig, sched: S, trace: Vec<JobSpec>) {
        self.cfg = cfg;
        self.sched = sched;
        self.events = EventQueue::new(self.cfg.event_queue);
        self.seq = 0;
        self.now = 0.0;
        self.jobs.clear();
        self.group_rt.clear();
        self.accts.clear();
        self.members.clear();
        self.high_water = f64::NEG_INFINITY;
        self.res = SimResult::default();
        self.open_world = false;
        self.last_rate_change = 0.0;
        self.cur_rate_per_h = 0.0;
        self.cur_roll_gpus = 0;
        self.cur_train_gpus = 0;
        self.emit_events = false;
        self.world_events.clear();
        self.load_trace(trace);
        let arm = self.cfg.record_flight && self.cfg.record_decisions;
        self.sched.set_record_provenance(arm);
    }

    /// Emit a push-channel event when armed (free when not: one branch).
    /// With the flight recorder on, the event also enters the frame
    /// stream — world events are coordinator-side only (never emitted
    /// inside a lane), so their recording order is deterministic on both
    /// the serial and the parallel path.
    fn world_event(&mut self, ev: WorldEvent) {
        if self.cfg.record_flight {
            self.res.flight.push(Frame::World(ev.clone()));
        }
        if self.emit_events {
            self.world_events.push(ev);
        }
    }

    fn push(&mut self, t: f64, ev: Ev) {
        self.seq += 1;
        self.events.push(t, self.seq, ev);
    }

    /// Streaming per-(group, node) rollout busy accumulation (GPU-s),
    /// routed to the group's arena slice (ISSUE 7). (Mirrored in
    /// `sim::fluid` — keep the accounting helpers in sync; the
    /// cross-tier property tests compare these integrals.)
    fn node_busy_add(&mut self, gid: usize, node: usize, gpu_s: f64) {
        self.accts.get_mut(gid).node_busy_add(node, gpu_s);
    }

    /// Streaming per-group training-pool busy accumulation (GPU-s).
    fn train_busy_add(&mut self, gid: usize, gpu_s: f64) {
        self.accts.get_mut(gid).train_busy_add(gpu_s);
    }

    fn members_add(&mut self, gid: usize, slot: usize) {
        if self.members.len() <= gid {
            self.members.resize_with(gid + 1, Vec::new);
        }
        self.members[gid].push(slot);
    }

    fn members_remove(&mut self, gid: usize, slot: usize) {
        if let Some(m) = self.members.get_mut(gid) {
            if let Some(i) = m.iter().position(|&s| s == slot) {
                m.remove(i);
            }
        }
    }

    fn integrate_cost(&mut self) {
        let dt_h = (self.now - self.last_rate_change) / 3600.0;
        self.res.cost_usd += dt_h * self.cur_rate_per_h;
        // provisioned GPU-seconds
        let dt = self.now - self.last_rate_change;
        self.res.roll_prov_gpu_s += dt * self.cur_roll_gpus as f64;
        self.res.train_prov_gpu_s += dt * self.cur_train_gpus as f64;
        self.last_rate_change = self.now;
    }

    fn rate_changed(&mut self) {
        self.integrate_cost();
        self.cur_rate_per_h = self.sched.cost_per_hour();
        let (r, t) = self.sched.gpus();
        self.cur_roll_gpus = r;
        self.cur_train_gpus = t;
        self.res.peak_roll_gpus = self.res.peak_roll_gpus.max(r);
        self.res.peak_train_gpus = self.res.peak_train_gpus.max(t);
        self.res.usage_curve.push((self.now, r, t));
    }

    /// Run to completion, returning the results.
    pub fn run(mut self) -> SimResult {
        self.run_to_end()
    }

    /// [`Self::run`] for a borrowed simulator: drains the loaded trace
    /// and takes the result out, leaving the slabs behind for the next
    /// [`Self::reset_with_trace`]. Also the open-world drain path: it
    /// closes the world (so the fault chain goes inert once every
    /// submitted job is settled — guaranteeing termination), processes
    /// everything still pending, and returns the final accounting.
    pub fn run_to_end(&mut self) -> SimResult {
        self.open_world = false;
        while let Some((t, ev)) = self.events.pop() {
            self.process_event(t, ev);
        }
        self.finalize()
    }

    /// Whether an event must run on the coordinator between windows
    /// (ISSUE 7): arrivals and faults/repairs touch the scheduler and
    /// can cross group boundaries; a job's FINAL sync completes it
    /// (scheduler retraction + cost re-integration + re-dispatch). A
    /// stale final sync (epoch-bumped) still reads as a barrier — the
    /// coordinator processes it exactly as the serial loop would, it
    /// just closes the window early.
    fn is_window_barrier(&self, ev: &Ev) -> bool {
        match *ev {
            Ev::Arrival(_) | Ev::Fault(_) | Ev::FaultRecover(..) => true,
            Ev::PhaseDone(slot, PhaseKind::Sync, iter, _) => {
                let rt = &self.jobs[slot];
                !rt.done && iter + 1 >= rt.spec.n_iters
            }
            _ => false,
        }
    }

    /// Move a group's state out into a lane for one parallel window.
    /// The lane's seq counter starts at the global snapshot: larger than
    /// every inherited seq and the barrier's, so lane-generated events
    /// sort after both at equal times — exactly where the serial loop's
    /// fresh seqs would put them.
    fn take_lane(&mut self, gid: usize, horizon: Option<(f64, u64)>) -> GroupLane {
        self.ensure_group_rt(gid);
        let member_slots: Vec<usize> = self.members.get(gid).cloned().unwrap_or_default();
        let mut jobs = Vec::with_capacity(member_slots.len());
        for s in member_slots {
            jobs.push((s, std::mem::replace(&mut self.jobs[s], JobRt::tombstone())));
        }
        let intra = self.cfg.intra;
        GroupLane {
            gid,
            jobs,
            orch: std::mem::replace(&mut self.group_rt[gid], GroupOrchestrator::new(intra)),
            acct: self.accts.take(gid),
            queue: LaneQueue::new(),
            seq: self.seq,
            horizon,
            now: f64::NEG_INFINITY,
            records: Vec::new(),
            flight: FlightRecorder::default(),
            hit_completion: false,
        }
    }

    /// Merge a drained lane back into the slabs. Called in ascending-gid
    /// order: jobs, orchestrator, accumulators, gantt records, clock
    /// high-water, then leftover events — re-pushed with fresh global
    /// seqs in lane pop order, the order the serial loop would have
    /// popped them (and, because re-push precedes the barrier's
    /// processing, ordered before any event the barrier generates at an
    /// equal time, again as in the serial loop).
    fn merge_lane(&mut self, lane: &mut GroupLane) {
        if lane.now > self.high_water {
            self.high_water = lane.now;
        }
        for (slot, rt) in lane.jobs.drain(..) {
            self.jobs[slot] = rt;
        }
        let intra = self.cfg.intra;
        self.group_rt[lane.gid] = std::mem::replace(&mut lane.orch, GroupOrchestrator::new(intra));
        self.accts.put(lane.gid, std::mem::take(&mut lane.acct));
        self.res.records.append(&mut lane.records);
        self.res.flight.append(&mut lane.flight);
        while let Some((t, _, ev)) = lane.queue.pop() {
            self.push(t, ev);
        }
    }

    /// Group-parallel run (ISSUE 7, DESIGN.md §15): bit-identical
    /// results to [`Self::run_to_end`], computed in windows. Between
    /// consecutive GLOBAL events — arrivals, faults, repairs, final
    /// syncs: the only events that touch the scheduler or cross group
    /// boundaries — every queued event is group-local, so each
    /// co-execution group's events drain independently: the window's
    /// events are stashed per group, the groups' lanes drain on a
    /// persistent worker pool (or inline for small windows — the SAME
    /// [`drain_lane`] either way), and the lanes merge back in ascending
    /// gid before the barrier itself runs on the coordinator.
    ///
    /// Determinism: lane seq counters start at the global counter
    /// snapshot (ordering lane-generated events after inherited ones at
    /// equal times, as serial fresh seqs would); leftovers re-enter the
    /// global queue in lane pop order with fresh seqs; and all f64
    /// accumulators are per-group chronological sums folded in gid order
    /// at [`Self::finalize`] — the same association the serial loop now
    /// uses. A final sync discovered mid-drain stops its lane
    /// (`hit_completion`, strictly before the window's barrier — see the
    /// seq argument above) and defers the barrier behind it: completions
    /// are global and must run on the coordinator in time order.
    ///
    /// `workers <= 1` falls through to the serial loop. Per-lane record
    /// and flight-recorder batches concatenate in gid order within a
    /// window rather than global time order — `finalize` canonically
    /// sorts both streams on BOTH paths (ISSUE 9), so recorded output is
    /// bit-identical to the serial loop's too (property-tested in
    /// `rust/tests/prop_snapshot.rs`).
    pub fn run_parallel(&mut self, workers: usize) -> SimResult {
        if workers <= 1 {
            return self.run_to_end();
        }
        self.open_world = false;
        let cfg = self.cfg.clone();
        std::thread::scope(|scope| {
            let (res_tx, res_rx) = std::sync::mpsc::channel::<GroupLane>();
            let mut lane_txs = Vec::with_capacity(workers);
            for _ in 0..workers {
                let (tx, rx) = std::sync::mpsc::channel::<GroupLane>();
                let out = res_tx.clone();
                let wcfg = cfg.clone();
                scope.spawn(move || {
                    let mut scratch: Vec<f64> = Vec::new();
                    for mut lane in rx {
                        drain_lane(&wcfg, &mut lane, &mut scratch);
                        if out.send(lane).is_err() {
                            break;
                        }
                    }
                });
                lane_txs.push(tx);
            }
            drop(res_tx);
            loop {
                // Stash group-local events up to (excluding) the next
                // global barrier.
                let mut barrier: Option<(f64, u64, Ev)> = None;
                let mut order: Vec<(usize, f64, u64, Ev)> = Vec::new();
                while let Some((t, seq, ev)) = self.events.pop_with_seq() {
                    if self.is_window_barrier(&ev) {
                        barrier = Some((t, seq, ev));
                        break;
                    }
                    let slot = match ev {
                        Ev::PhaseDone(slot, ..) | Ev::TailFree(slot, ..) | Ev::Recover(slot, _) => slot,
                        Ev::Arrival(_) | Ev::Fault(_) | Ev::FaultRecover(..) => {
                            unreachable!("global events are window barriers")
                        }
                    };
                    if self.jobs[slot].done {
                        // Stale events of settled jobs, inline — exactly
                        // the serial loop's effect: they only advance the
                        // clock and the event count (a superseded Recover
                        // touches neither, per the pre-guard).
                        if !matches!(ev, Ev::Recover(..)) {
                            self.high_water = self.high_water.max(t);
                            self.res.events_processed += 1;
                        }
                        continue;
                    }
                    order.push((self.jobs[slot].group, t, seq, ev));
                }
                // One lane per group touched this window, in
                // first-encounter (time) order; stashed events keep
                // their original (t, seq) keys.
                let hkey = barrier.as_ref().map(|&(t, s, _)| (t, s));
                let mut pending: Vec<GroupLane> = Vec::new();
                {
                    let mut lane_of: HashMap<usize, usize> = HashMap::new();
                    for (gid, t, seq, ev) in order {
                        let idx = match lane_of.get(&gid) {
                            Some(&i) => i,
                            None => {
                                pending.push(self.take_lane(gid, hkey));
                                lane_of.insert(gid, pending.len() - 1);
                                pending.len() - 1
                            }
                        };
                        pending[idx].queue.push(t, seq, ev);
                    }
                }
                let stashed: usize = pending.iter().map(|l| l.queue.len()).sum();
                if pending.len() > 1 && stashed >= PAR_WINDOW_MIN_EVENTS {
                    let n = pending.len();
                    for (i, lane) in pending.drain(..).enumerate() {
                        lane_txs[i % workers].send(lane).expect("worker alive");
                    }
                    for _ in 0..n {
                        pending.push(res_rx.recv().expect("worker returns lane"));
                    }
                } else {
                    for lane in &mut pending {
                        drain_lane(&cfg, lane, &mut self.scratch_lengths);
                    }
                }
                // Merge in ascending gid (collection order off the
                // results channel is racy; gids are unique per window).
                pending.sort_by_key(|l| l.gid);
                let deferred = pending.iter().any(|l| l.hit_completion);
                for mut lane in pending {
                    self.merge_lane(&mut lane);
                }
                match barrier {
                    Some((t, seq, ev)) => {
                        if deferred {
                            // A lane stopped at a final sync strictly
                            // before the barrier: re-queue the barrier
                            // under its ORIGINAL key; the next window
                            // pops the completion first.
                            self.events.push(t, seq, ev);
                        } else {
                            self.process_event(t, ev);
                        }
                    }
                    None => {
                        if self.events.is_empty() {
                            break;
                        }
                    }
                }
            }
            drop(lane_txs);
        });
        self.finalize()
    }

    /// Jobs that reached a terminal state (completed or cancelled).
    fn settled(&self) -> usize {
        self.res.outcomes.len() + self.res.cancelled
    }

    /// One event through the engine state machine — the loop body of
    /// [`Self::run_to_end`], shared verbatim by the open-world stepping
    /// API (ISSUE 6) so incremental driving is bit-identical to batch.
    fn process_event(&mut self, t: f64, ev: Ev) {
        // Fault/repair events outliving the workload are inert:
        // don't let them advance the clock past the last completion
        // (the chain stops re-arming once all jobs finish). An open
        // world has no "after the workload" — a live cluster's nodes
        // keep failing while it idles — so the guard is batch-only.
        if matches!(ev, Ev::Fault(_) | Ev::FaultRecover(..))
            && !self.open_world
            && self.settled() == self.trace.len()
        {
            return;
        }
        // A superseded recovery (its victim was re-crashed before it
        // fired) is pure noise; unlike stale phase events — which
        // always precede their job's eventual completion — it can
        // outlive the whole workload, so it must not touch the
        // clock/makespan. (Recover only exists under faults, keeping
        // fault-free runs bit-identical.)
        if let Ev::Recover(slot, ep) = ev {
            if self.jobs[slot].done || self.jobs[slot].epoch != ep {
                return;
            }
        }
        debug_assert!(t >= self.now - 1e-9, "time went backwards");
        self.now = t;
        self.res.events_processed += 1;
        match ev {
            Ev::Arrival(i) => self.on_arrival(i),
            Ev::PhaseDone(slot, ..) => {
                let gid = self.jobs[slot].group;
                if let Some(slot) = self.dispatch_local(gid, ev) {
                    self.finish_job(slot);
                }
            }
            Ev::TailFree(slot, ..) | Ev::Recover(slot, _) => {
                let gid = self.jobs[slot].group;
                self.dispatch_local(gid, ev);
            }
            Ev::Fault(idx) => self.on_fault(idx),
            Ev::FaultRecover(gid, node) => self.on_fault_recover(gid, node),
        }
    }

    /// Close the books: integrate the cost tail, stamp the makespan,
    /// fold the per-group arena accumulators (ascending gid — the fixed
    /// deterministic order shared by the serial and parallel loops,
    /// DESIGN.md §15), and take the result out of the slab.
    fn finalize(&mut self) -> SimResult {
        // The group-parallel drain advances lanes past the last global
        // barrier; the serial loop never sets the high-water above now.
        if self.high_water > self.now {
            self.now = self.high_water;
        }
        self.high_water = f64::NEG_INFINITY;
        self.integrate_cost();
        self.res.makespan_s = self.now;
        self.res.avg_cost_per_hour = if self.now > 0.0 {
            self.res.cost_usd / (self.now / 3600.0)
        } else {
            0.0
        };
        let n = self.accts.len();
        for gid in 0..n {
            let a = self.accts.get_mut(gid);
            self.res.roll_busy_gpu_s += a.roll_busy_gpu_s;
            self.res.train_busy_gpu_s += a.train_busy_gpu_s;
            self.res.events_processed += a.events;
        }
        // Dimensional reconstruction preserves the old resize-on-write
        // semantics: the flat vectors extend exactly to the last group
        // that ever wrote them (zero-valued writes included).
        if let Some(last) =
            (0..n).rev().find(|&g| self.accts.get(g).map_or(false, |a| !a.node_busy_gpu_s.is_empty()))
        {
            self.res.roll_node_busy_gpu_s.resize_with(last + 1, Vec::new);
            for gid in 0..=last {
                self.res.roll_node_busy_gpu_s[gid] =
                    std::mem::take(&mut self.accts.get_mut(gid).node_busy_gpu_s);
            }
        }
        if let Some(last) = (0..n).rev().find(|&g| self.accts.get(g).map_or(false, |a| a.train_touched)) {
            self.res.train_group_busy_gpu_s.resize(last + 1, 0.0);
            for gid in 0..=last {
                self.res.train_group_busy_gpu_s[gid] =
                    self.accts.get(gid).map_or(0.0, |a| a.train_busy_gpu_s);
            }
        }
        self.accts.clear();
        // Canonical total order for both recorded streams (ISSUE 9): the
        // serial loop appends in global time order, the parallel drain in
        // gid-batched window order — the sort key is a total order whose
        // ties only occur between bit-identical entries, so both paths
        // finish with the exact same sequence.
        canonical_sort_records(&mut self.res.records);
        self.res.flight.canonical_sort();
        // Persist the finalized stream as an RMTRC01 archive (ISSUE 10).
        // After the canonical sort, so a batch archive is byte-identical
        // between serial and parallel producers. I/O failure warns: a
        // full simulation result must not be lost to a bad path.
        if let Some(path) = &self.cfg.trace_path {
            if let Err(e) = crate::obs::FlightArchive::write(path, self.res.flight.frames()) {
                eprintln!("rollmux: trace archive write to {} failed: {e}", path.display());
            }
        }
        std::mem::take(&mut self.res)
    }

    fn ensure_group_rt(&mut self, gid: usize) {
        let intra = self.cfg.intra;
        if self.group_rt.len() <= gid {
            self.group_rt.resize_with(gid + 1, || GroupOrchestrator::new(intra));
        }
    }

    fn on_arrival(&mut self, idx: usize) {
        let spec = self.trace[idx].take().expect("arrival fires once per job");
        let id = spec.id;
        let d = self.sched.place(spec.clone());
        // Decision provenance (ISSUE 10): the placement verdict plus the
        // per-candidate Δ scores the armed scheduler captured. Arrivals
        // are window barriers (coordinator-side), so the emission order
        // is deterministic on both engine paths.
        if self.cfg.record_flight && self.cfg.record_decisions {
            let considered = self
                .sched
                .take_placement_provenance()
                .map(|p| p.considered.into_iter().map(|c| (c.gid, c.delta_cost)).collect())
                .unwrap_or_default();
            self.res.flight.push(Frame::Placement {
                t: self.now,
                job: id,
                gid: d.group_id,
                kind_tag: placement_kind_tag(&d.kind),
                marginal_cost: d.marginal_cost,
                considered,
            });
        }
        self.rate_changed();

        let group = self.sched.group(d.group_id).expect("placed group exists");
        let gj = group.jobs().iter().find(|j| j.spec.id == id).expect("job in group");
        let train_gpus = group.train_gpus();
        let train_scale = if matches!(spec.phases, PhaseSpec::Direct { .. }) {
            1.0
        } else {
            spec.n_train_gpus as f64 / train_gpus as f64
        };
        let t_sync = sync_time_s(
            self.cfg.sync_scheme,
            spec.model_bytes(),
            train_gpus,
            spec.n_roll_gpus,
        );
        let solo_est_iter_s = gj.t_solo();
        let cold = self.cfg.switch.cold_s(spec.params_b, crate::cluster::node::PoolKind::Rollout);
        let mut rng = Rng::new(self.cfg.seed ^ (id as u64).wrapping_mul(0x9E37_79B9));
        let rt = JobRt {
            group: d.group_id,
            roll_nodes: d.roll_nodes,
            train_gpus,
            train_scale,
            t_sync,
            iter: 0,
            solo_s: 0.0,
            solo_est_iter_s,
            init_s: cold,
            migrations: 0,
            rng: rng.fork(1),
            cur_troll: 0.0,
            cur_ttrain: 0.0,
            cur_roll_end: 0.0,
            tail_penalty: 0.0,
            tail_frac: 0.0,
            done: false,
            epoch: 0,
            phase: None,
            phase_start_s: 0.0,
            cur_train_end: 0.0,
            iter_sampled: false,
            iter_busy_gpu_s: 0.0,
            iter_wasted_gpu_s: 0.0,
            consolidated: false,
            pending_tail: None,
            recoveries: 0,
            recovery_s: 0.0,
            spec,
        };
        let slot = self.jobs.len();
        self.jobs.push(rt);
        self.job_slot.insert(id, slot);
        self.ensure_group_rt(d.group_id);
        {
            // Register with the group's orchestration core: the job's
            // pinned nodes plus its static SLO budget (slo x T_solo, the
            // SloSlackPriority key).
            let rt = &self.jobs[slot];
            let slack = rt.spec.slo * rt.solo_est_iter_s;
            let nodes = rt.roll_nodes.clone();
            self.group_rt[d.group_id].admit(slot, id, nodes, slack);
        }
        self.members_add(d.group_id, slot);

        // One-time Init (cold start of the job's state into the caches).
        let t_done = self.now + cold;
        self.record(slot, PhaseKind::Init, 0, self.now, t_done, &[]);
        self.push(t_done, Ev::PhaseDone(slot, PhaseKind::Init, 0, 0));
    }

    /// Build the group-local execution view over the simulator's own
    /// slabs — the serial loop's [`LaneCtx`]. The borrows are
    /// field-disjoint: `jobs` / `group_rt[gid]` / `accts` /
    /// `events`+`seq` / `scratch_lengths` / `res.records` never alias.
    fn lane_ctx(&mut self, gid: usize) -> LaneCtx<'_> {
        self.ensure_group_rt(gid);
        LaneCtx {
            cfg: &self.cfg,
            jobs: Slots::Slab(&mut self.jobs),
            orch: &mut self.group_rt[gid],
            acct: self.accts.get_mut(gid),
            sink: Sink::Global { events: &mut self.events, seq: &mut self.seq },
            now: self.now,
            scratch: &mut self.scratch_lengths,
            records: &mut self.res.records,
            flight: &mut self.res.flight,
        }
    }

    /// Route one group-local event through the shared [`LaneCtx`] state
    /// machine. `Some(slot)` means the job's final sync completed and
    /// the caller owns the global completion ([`Self::finish_job`]).
    fn dispatch_local(&mut self, gid: usize, ev: Ev) -> Option<usize> {
        self.lane_ctx(gid).dispatch(ev)
    }

    /// Drain the group's orchestration core
    /// ([`LaneCtx::drain_dispatch`]) — the coordinator-side wrapper used
    /// after global mutations: crashes, repairs, completions,
    /// cancellations.
    fn drain_dispatch(&mut self, gid: usize) {
        self.lane_ctx(gid).drain_dispatch();
    }

    /// Apply the pending fault event, then keep the stream armed while
    /// any job is still outstanding (ISSUE 5).
    fn on_fault(&mut self, handle: usize) {
        let fe = self.faults_rt.as_ref().expect("fault event without a stream").event(handle);
        match fe.kind {
            FaultKind::NodeCrash { repair_s } => self.apply_crash(fe.victim, repair_s),
            FaultKind::Straggler { factor } => self.apply_straggler(fe.victim, factor),
        }
        if self.open_world || self.settled() < self.trace.len() {
            if let Some((h, t)) = self.faults_rt.as_mut().and_then(FaultStream::pull) {
                self.push(t.max(self.now), Ev::Fault(h));
            }
        }
    }

    /// A rollout node dies (ISSUE 5, DESIGN.md §13). The scheduler heals
    /// the group (`coordinator::repair`: repin survivors, spill the
    /// rest); the engine translates each member fate into an interrupt +
    /// checkpoint-aware recovery, holds the node down until its repair
    /// completes, and keeps the busy/goodput accounting consistent.
    fn apply_crash(&mut self, victim: u64, repair_s: f64) {
        let Some((gid, node)) = repair::pick_victim(self.sched.groups(), victim) else {
            return; // nothing provisioned right now
        };
        self.crash_node(gid, node, repair_s);
    }

    /// Crash a *named* (group, group-local node) — the body of
    /// [`Self::apply_crash`] once the opaque victim draw is resolved,
    /// also the entry point for daemon-injected faults and heartbeat
    /// escalation ([`Self::inject_node_crash`], ISSUE 6).
    fn crash_node(&mut self, gid: usize, node: usize, repair_s: f64) {
        self.res.crashes += 1;
        self.world_event(WorldEvent::Crash { t: self.now, gid, node });
        let outcome = self.sched.repair_node_crash(gid, node);
        self.ensure_group_rt(gid);
        if let Some(out) = outcome {
            self.rate_changed();
            for fate in &out.fates {
                let jid = fate.job();
                let Some(&slot) = self.job_slot.get(&jid) else { continue };
                if self.jobs[slot].done {
                    continue;
                }
                self.interrupt(slot);
                let repinned = matches!(fate, MemberFate::Repinned { .. });
                match fate {
                    MemberFate::Repinned { roll_nodes, .. } => {
                        self.jobs[slot].roll_nodes = roll_nodes.clone();
                        self.group_rt[gid].set_roll_nodes(slot, roll_nodes.clone());
                        self.res.evictions += 1;
                    }
                    MemberFate::Spilled { decision, .. } => {
                        self.group_rt[gid].complete(slot);
                        self.respill(slot, decision);
                        self.res.spills += 1;
                    }
                }
                let to_gid = match fate {
                    MemberFate::Repinned { .. } => gid,
                    MemberFate::Spilled { decision, .. } => decision.group_id,
                };
                self.world_event(WorldEvent::Repair { t: self.now, job: jid, gid, to_gid, repinned });
                let params_b = self.jobs[slot].spec.params_b;
                let delay = repair::recovery_delay_s(
                    &self.cfg.switch,
                    &self.cfg.migration,
                    params_b,
                    repinned,
                );
                // Decision provenance (ISSUE 10): this victim's fate and
                // the recovery delay it was charged. Crashes are window
                // barriers, so the emission order is deterministic.
                if self.cfg.record_flight && self.cfg.record_decisions {
                    self.res.flight.push(Frame::Repair {
                        t: self.now,
                        gid,
                        node,
                        job: jid,
                        to_gid,
                        repinned,
                        delay_s: delay,
                    });
                }
                let ep = {
                    let rt = &mut self.jobs[slot];
                    rt.recoveries += 1;
                    rt.recovery_s += delay;
                    rt.epoch
                };
                self.res.recovery_time_s += delay;
                self.push(self.now + delay, Ev::Recover(slot, ep));
            }
        }
        // Hold the node down until the repair completes (schedulers
        // without repair support still get this; their resident phases
        // run out and new dispatches wait). Overlapping crashes extend
        // the window: only the latest deadline's recover lifts it.
        self.group_rt[gid].set_node_down(node);
        let until = self.now + repair_s;
        let dl = self.node_down_until.entry((gid, node)).or_insert(f64::NEG_INFINITY);
        if until > *dl {
            *dl = until;
        }
        self.push(until, Ev::FaultRecover(gid, node));
        self.drain_dispatch(gid);
    }

    /// Move a spilled victim's runtime state into its new group: the
    /// training pool (and hence DP rescale + sync time) follows the new
    /// placement; the SLO reference (solo estimate) is fixed at original
    /// admission.
    fn respill(&mut self, slot: usize, d: &Decision) {
        let old_gid = self.jobs[slot].group;
        self.members_remove(old_gid, slot);
        let train_gpus = self.sched.group(d.group_id).expect("spill target exists").train_gpus();
        self.ensure_group_rt(d.group_id);
        let (jid, nodes, slack) = {
            let rt = &mut self.jobs[slot];
            rt.group = d.group_id;
            rt.roll_nodes = d.roll_nodes.clone();
            rt.train_gpus = train_gpus;
            rt.train_scale = if matches!(rt.spec.phases, PhaseSpec::Direct { .. }) {
                1.0
            } else {
                rt.spec.n_train_gpus as f64 / train_gpus as f64
            };
            rt.t_sync = sync_time_s(
                self.cfg.sync_scheme,
                rt.spec.model_bytes(),
                train_gpus,
                rt.spec.n_roll_gpus,
            );
            (rt.spec.id, rt.roll_nodes.clone(), rt.spec.slo * rt.solo_est_iter_s)
        };
        self.group_rt[d.group_id].admit(slot, jid, nodes, slack);
        self.members_add(d.group_id, slot);
    }

    /// Interrupt a crash victim: truncate the in-flight phase's busy
    /// integrals (the un-run remainder never happens), charge EVERYTHING
    /// the discarded iteration had accrued — completed phases included —
    /// as wasted work, cancel its pending events via an epoch bump, and
    /// release everything it holds or queues in its group.
    fn interrupt(&mut self, slot: usize) {
        let gid = self.jobs[slot].group;
        let now = self.now;
        let phase = self.jobs[slot].phase;
        match phase {
            Some(PhaseKind::Rollout) if self.jobs[slot].cur_roll_end > now => {
                let remaining = self.jobs[slot].cur_roll_end - now;
                let n_pins = self.jobs[slot].roll_nodes.len();
                // A consolidated tail already reshaped the integrals
                // (`on_tail_free` credited the freed nodes back), so the
                // plain full-pin remainder subtraction would double-cut;
                // the sub-node residual (≤ tail + pause) is left as-is.
                if !self.jobs[slot].consolidated {
                    let cut = remaining * n_pins as f64 * GPUS_PER_NODE as f64;
                    self.accts.get_mut(gid).roll_busy_gpu_s -= cut;
                    self.jobs[slot].iter_busy_gpu_s -= cut;
                    for i in 0..n_pins {
                        let n = self.jobs[slot].roll_nodes[i];
                        self.node_busy_add(gid, n, -remaining * GPUS_PER_NODE as f64);
                    }
                }
            }
            Some(PhaseKind::Train) if self.jobs[slot].cur_train_end > now => {
                let remaining = self.jobs[slot].cur_train_end - now;
                let tg = self.jobs[slot].train_gpus as f64;
                self.jobs[slot].iter_busy_gpu_s -= remaining * tg;
                self.train_busy_add(gid, -remaining * tg);
            }
            _ => {}
        }
        // The whole in-flight iteration rolls back to its checkpoint:
        // what actually ran of it (the accrual minus the truncations
        // above) is discarded work, whatever sub-phase the crash hit —
        // minus overhead the straggler path already charged to wasted.
        let rt = &mut self.jobs[slot];
        self.res.wasted_gpu_s += (rt.iter_busy_gpu_s - rt.iter_wasted_gpu_s).max(0.0);
        rt.iter_busy_gpu_s = 0.0;
        rt.iter_wasted_gpu_s = 0.0;
        rt.consolidated = false;
        rt.epoch = rt.epoch.wrapping_add(1);
        rt.phase = None;
        rt.tail_penalty = 0.0;
        rt.pending_tail = None;
        self.group_rt[gid].cancel_queued(slot);
        self.group_rt[gid].release_rollout(slot);
        self.group_rt[gid].release_train(slot);
    }

    /// A straggling node slows every in-flight rollout pinned to it: the
    /// data-parallel batch gates on the slow node, so the whole pin set
    /// stays busy for the stretched remainder (overhead → wasted). The
    /// pending completion is re-scheduled via an epoch bump, and an
    /// armed tail consolidation is re-armed at its stretched trigger
    /// (not cancelled). Already-consolidated tails (sub-node residuals)
    /// are left alone. The scan is bounded to the damaged group's
    /// members (admission order — deterministic), not the whole slab.
    fn apply_straggler(&mut self, victim: u64, factor: f64) {
        let Some((gid, node)) = repair::pick_victim(self.sched.groups(), victim) else {
            return;
        };
        self.straggle_node(gid, node, factor);
    }

    /// Slow a *named* (group, group-local node) — the resolved body of
    /// [`Self::apply_straggler`], shared with the daemon's injected
    /// straggler path ([`Self::inject_straggler`], ISSUE 6).
    fn straggle_node(&mut self, gid: usize, node: usize, factor: f64) {
        if factor <= 1.0 {
            return;
        }
        let slots: Vec<usize> = match self.sched.group(gid) {
            Some(g) => g
                .jobs()
                .iter()
                .filter(|j| j.roll_nodes.contains(&node))
                .filter_map(|j| self.job_slot.get(&j.spec.id).copied())
                .collect(),
            None => return,
        };
        let mut any = false;
        for slot in slots {
            {
                let rt = &self.jobs[slot];
                if rt.done
                    || rt.phase != Some(PhaseKind::Rollout)
                    || rt.cur_roll_end <= self.now
                    // A consolidated tail occupies a sub-node residual
                    // the straggler model (full-pin stretch) does not
                    // describe; leave it to run out.
                    || rt.consolidated
                    || !rt.roll_nodes.contains(&node)
                {
                    continue;
                }
            }
            let (extra, n_pins, iter) = {
                let rt = &mut self.jobs[slot];
                let remaining = rt.cur_roll_end - self.now;
                let extra = remaining * (factor - 1.0);
                rt.cur_roll_end += extra;
                rt.epoch = rt.epoch.wrapping_add(1);
                (extra, rt.roll_nodes.len(), rt.iter)
            };
            let gpu_extra = extra * n_pins as f64 * GPUS_PER_NODE as f64;
            self.accts.get_mut(gid).roll_busy_gpu_s += gpu_extra;
            for i in 0..n_pins {
                let n = self.jobs[slot].roll_nodes[i];
                self.node_busy_add(gid, n, extra * GPUS_PER_NODE as f64);
            }
            // The stretch is wasted immediately; it also enters the
            // iteration accrual (keeping it in lockstep with the busy
            // integrals) with `iter_wasted_gpu_s` recording that this
            // part is already charged — a later crash wastes only the
            // difference, never double-counting the overhead.
            self.res.wasted_gpu_s += gpu_extra;
            {
                let rt = &mut self.jobs[slot];
                rt.iter_busy_gpu_s += gpu_extra;
                rt.iter_wasted_gpu_s += gpu_extra;
            }
            let (end, ep) = (self.jobs[slot].cur_roll_end, self.jobs[slot].epoch);
            self.push(end, Ev::PhaseDone(slot, PhaseKind::Rollout, iter, ep));
            // Re-arm an unfired tail consolidation at its stretched
            // trigger (the epoch bump made the original check stale).
            if let Some((t_check, kept)) = self.jobs[slot].pending_tail {
                let stretched = if t_check > self.now {
                    self.now + (t_check - self.now) * factor
                } else {
                    t_check
                };
                self.jobs[slot].pending_tail = Some((stretched, kept));
                self.push(stretched.max(self.now), Ev::TailFree(slot, kept, ep));
            }
            any = true;
        }
        if any {
            self.res.stragglers += 1;
            self.world_event(WorldEvent::Straggle { t: self.now, gid, node, factor });
        }
    }

    /// A crashed node's repair completed: it rejoins the pool — unless a
    /// later crash extended the down window, in which case this recover
    /// is superseded and the node stays down until the latest deadline.
    fn on_fault_recover(&mut self, gid: usize, node: usize) {
        if self.group_rt.len() <= gid {
            return;
        }
        if let Some(&dl) = self.node_down_until.get(&(gid, node)) {
            if self.now + 1e-9 < dl {
                return; // superseded by a later crash's repair
            }
            self.node_down_until.remove(&(gid, node));
        }
        self.group_rt[gid].set_node_up(node);
        self.world_event(WorldEvent::NodeUp { t: self.now, gid, node });
        self.drain_dispatch(gid);
    }

    fn finish_job(&mut self, slot: usize) {
        let (id, gid, outcome) = {
            let rt = &mut self.jobs[slot];
            rt.done = true;
            rt.phase = None;
            (
                rt.spec.id,
                rt.group,
                JobOutcome {
                    arrival_s: rt.spec.arrival_s,
                    finish_s: self.now,
                    solo_actual_s: rt.solo_s,
                    solo_est_s: rt.init_s + rt.solo_est_iter_s * rt.spec.n_iters as f64,
                    slo: rt.spec.slo,
                    iters: rt.iter,
                    migrations: rt.migrations,
                    recoveries: rt.recoveries,
                    recovery_s: rt.recovery_s,
                },
            )
        };
        self.res.outcomes.insert(id, outcome);
        self.world_event(WorldEvent::Done { t: self.now, job: id });
        self.group_rt[gid].complete(slot);
        self.members_remove(gid, slot);
        self.sched.complete(id);
        self.rate_changed();
        // Re-dispatch in case the group shrank / freed capacity.
        self.drain_dispatch(gid);
    }

    fn record(&mut self, slot: usize, kind: PhaseKind, iter: usize, start: f64, end: f64, roll_nodes: &[usize]) {
        if self.cfg.record_gantt || self.cfg.record_flight {
            let rt = &self.jobs[slot];
            let rec = PhaseRecord {
                job: rt.spec.id,
                group: rt.group,
                kind,
                iter,
                start,
                end,
                roll_nodes: roll_nodes.to_vec(),
            };
            emit_phase(
                self.cfg.record_gantt,
                self.cfg.record_flight,
                &mut self.res.records,
                &mut self.res.flight,
                rec,
            );
        }
    }

    // ------------------------------------------------------------------
    // Open-world / virtual-cluster API (ISSUE 6, DESIGN.md §14).
    //
    // `rollmuxd` drives the engine as a live deterministic cluster:
    // jobs arrive one at a time (`submit`), virtual time advances in
    // explicit increments (`step_until`), faults are injected by name
    // (`inject_node_crash` / `inject_straggler`), and shutdown drains
    // through the ordinary `run_to_end`. Every method below routes
    // through the exact same `process_event` state machine as batch
    // runs, so a command sequence replayed from the daemon's journal
    // reproduces the pre-crash state bit for bit.
    // ------------------------------------------------------------------

    /// Open an empty virtual cluster: no pre-loaded trace; jobs arrive
    /// via [`Self::submit`] and time advances via [`Self::step_until`].
    /// The chaos stream (`cfg.faults`) is armed exactly as in batch
    /// mode, and — unlike batch mode — keeps firing while the cluster
    /// idles. Submitting a whole trace up-front and then calling
    /// [`Self::run_to_end`] is bit-identical to
    /// `Simulator::new(cfg, sched, trace).run()` (unit-tested below).
    pub fn open(cfg: SimConfig, sched: S) -> Self {
        let mut sim = Simulator::new(cfg, sched, Vec::new());
        sim.open_world = true;
        sim
    }

    /// Current virtual time, seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Jobs submitted but not yet settled (completed or cancelled) —
    /// includes admitted jobs whose arrival event has not fired yet.
    pub fn outstanding(&self) -> usize {
        self.trace.len() - self.settled()
    }

    /// Submit one job into the open world. The arrival is clamped to
    /// the current virtual time (events cannot fire in the past);
    /// returns the effective arrival time. The caller usually follows
    /// with `step_until(sim.now())` so the placement happens
    /// synchronously and can be inspected via [`Self::job_placement`].
    pub fn submit(&mut self, mut spec: JobSpec) -> f64 {
        let t = spec.arrival_s.max(self.now);
        spec.arrival_s = t;
        let idx = self.trace.len();
        self.trace.push(Some(spec));
        self.push(t, Ev::Arrival(idx));
        t
    }

    /// Process every pending event due at or before `deadline`, then
    /// advance the clock to `deadline` (idle time passes too: cost
    /// integration and heartbeat expiry both need the clock to move on
    /// a quiet cluster). Events processed here are bit-identical to the
    /// batch loop — only the stopping point differs.
    pub fn step_until(&mut self, deadline: f64) {
        while let Some((t, ev)) = self.events.pop_at_or_before(deadline) {
            self.process_event(t, ev);
        }
        if deadline > self.now {
            self.now = deadline;
        }
    }

    /// Process the single next pending event (the daemon's drain loop
    /// alternates this with admission-queue pumping). Returns the clock
    /// after the event, or `None` when nothing is pending.
    pub fn step_one(&mut self) -> Option<f64> {
        let (t, ev) = self.events.pop()?;
        self.process_event(t, ev);
        Some(self.now)
    }

    /// Whether a submitted job reached completion (or cancellation).
    pub fn job_done(&self, id: JobId) -> bool {
        match self.job_slot.get(&id) {
            Some(&slot) => self.jobs[slot].done,
            None => false,
        }
    }

    /// A live job's current placement: (group id, pinned rollout
    /// nodes). `None` until its arrival event has fired.
    pub fn job_placement(&self, id: JobId) -> Option<(usize, &[usize])> {
        let &slot = self.job_slot.get(&id)?;
        let rt = &self.jobs[slot];
        Some((rt.group, &rt.roll_nodes[..]))
    }

    /// Withdraw a live job (ISSUE 6): interrupt whatever it is running
    /// (truncating the busy integrals, charging the discarded iteration
    /// as wasted work — same bookkeeping as a crash interrupt), release
    /// everything it holds, and retract it from the scheduler so its
    /// capacity frees immediately. Returns false for unknown/finished
    /// jobs (idempotent).
    pub fn cancel_job(&mut self, id: JobId) -> bool {
        let Some(&slot) = self.job_slot.get(&id) else {
            return false;
        };
        if self.jobs[slot].done {
            return false;
        }
        self.interrupt(slot);
        let gid = self.jobs[slot].group;
        self.jobs[slot].done = true;
        self.group_rt[gid].complete(slot);
        self.members_remove(gid, slot);
        self.sched.complete(id);
        self.res.cancelled += 1;
        self.rate_changed();
        self.drain_dispatch(gid);
        true
    }

    /// Snapshot the usage accounting before a trial admission.
    pub fn usage_mark(&self) -> AdmissionMark {
        AdmissionMark {
            peak_roll: self.res.peak_roll_gpus,
            peak_train: self.res.peak_train_gpus,
            curve_len: self.res.usage_curve.len(),
        }
    }

    /// Undo a trial admission: cancel the job and restore the
    /// peak/usage-curve accounting captured by [`Self::usage_mark`], so
    /// a capacity-rejected admission leaves no transient spike in the
    /// final accounting (it still counts under `SimResult::cancelled`).
    /// No virtual time may pass between the mark and the rollback.
    pub fn rollback_admission(&mut self, id: JobId, mark: AdmissionMark) -> bool {
        if !self.cancel_job(id) {
            return false;
        }
        self.res.peak_roll_gpus = mark.peak_roll;
        self.res.peak_train_gpus = mark.peak_train;
        self.res.usage_curve.truncate(mark.curve_len);
        true
    }

    /// Crash a named (group, group-local rollout node) at the current
    /// virtual time — the daemon's fault-injection and heartbeat-
    /// escalation entry point. Routes through the same repair surgery
    /// as stream faults ([`GroupScheduler::repair_node_crash`] → member
    /// interrupts → checkpoint-aware recovery). Returns false when the
    /// target does not exist right now (a transient repair failure the
    /// daemon retries with backoff).
    pub fn inject_node_crash(&mut self, gid: usize, node: usize, repair_s: f64) -> bool {
        let ok = match self.sched.group(gid) {
            Some(g) => node < g.n_roll_nodes,
            None => false,
        };
        if !ok || !repair_s.is_finite() || repair_s < 0.0 {
            return false;
        }
        self.crash_node(gid, node, repair_s);
        true
    }

    /// Slow a named (group, group-local rollout node) by `factor` at
    /// the current virtual time. Returns false when the target does not
    /// exist or the factor is not a finite slowdown (> 1).
    pub fn inject_straggler(&mut self, gid: usize, node: usize, factor: f64) -> bool {
        let ok = match self.sched.group(gid) {
            Some(g) => node < g.n_roll_nodes,
            None => false,
        };
        if !ok || factor <= 1.0 || !factor.is_finite() {
            return false;
        }
        self.straggle_node(gid, node, factor);
        true
    }

    /// Arm (or disarm) [`WorldEvent`] recording for the push channel
    /// (ISSUE 8). Disarming drops anything recorded but not yet drained.
    pub fn arm_events(&mut self, on: bool) {
        self.emit_events = on;
        if !on {
            self.world_events.clear();
        }
    }

    /// Drain every [`WorldEvent`] recorded since the last drain, in
    /// emission order (the engine is serial, so this order is the
    /// deterministic causal order).
    pub fn take_world_events(&mut self) -> Vec<WorldEvent> {
        std::mem::take(&mut self.world_events)
    }

    /// Drain every flight-recorder [`Frame`] buffered since the last
    /// drain, in emission order — the daemon's incremental metrics bus
    /// (ISSUE 9). Empty unless `cfg.record_flight` is armed. Recording
    /// is part of the deterministic state machine, so a journal replay
    /// re-records (and re-drains) the identical frame sequence. Batch
    /// runs should NOT drain mid-run: frames left in place are
    /// canonically sorted into [`SimResult::flight`] at finalize.
    pub fn take_frames(&mut self) -> Vec<Frame> {
        self.res.flight.drain()
    }

    /// Process every pending event due at or before `deadline`, WITHOUT
    /// advancing the clock past the last processed event — unlike
    /// [`Self::step_until`], which models idle wall-time passing. This
    /// is the fork primitive (ISSUE 9): a snapshot taken after
    /// `run_until(t)` captures exactly the prefix of the run up to `t`,
    /// with the makespan clock still owned by real events, so a forked
    /// continuation is bit-identical to an uninterrupted run.
    pub fn run_until(&mut self, deadline: f64) {
        while let Some((t, ev)) = self.events.pop_at_or_before(deadline) {
            self.process_event(t, ev);
        }
    }

    /// Live intra-group policy swap (ISSUE 8): future groups build with
    /// the new policy (`ensure_group_rt` reads `cfg.intra`), and every
    /// existing orchestrator rebuilds its policy with the survivors
    /// re-admitted in admission order. In-flight grants are untouched —
    /// the current cycle drains under the grants it holds; the next pick
    /// follows the new policy. A work-conserving invariant makes this
    /// safe without a forced re-dispatch (no policy leaves a feasible
    /// request unpicked), but we drain any non-empty queues anyway so a
    /// swap is always immediately visible.
    pub fn set_intra_policy(&mut self, kind: IntraPolicyKind) {
        self.cfg.intra = kind;
        for orc in &mut self.group_rt {
            orc.set_policy(kind);
        }
        for gid in 0..self.group_rt.len() {
            if self.group_rt[gid].queue_len() > 0 {
                self.drain_dispatch(gid);
            }
        }
    }

    /// Live group-cap reconfiguration (ISSUE 8): forward the new cap to
    /// the scheduler ([`GroupScheduler::set_group_cap`]) and translate
    /// each displaced member exactly like a crash-repair spill —
    /// interrupt the in-flight iteration (busy-integral truncation,
    /// wasted-work charge), move its runtime state to the new placement,
    /// and charge the checkpoint-aware cold-restart delay before the
    /// replay (`Ev::Recover`). No node goes down: displacement costs the
    /// victims, never the survivors. Returns `None` when the scheduler
    /// has no cap support (baselines), `Some(outcomes)` otherwise.
    pub fn reconfig_group_cap(&mut self, cap: Option<usize>) -> Option<Vec<ShrinkOutcome>> {
        let outcomes = self.sched.set_group_cap(cap)?;
        if outcomes.is_empty() {
            return Some(outcomes);
        }
        self.rate_changed();
        for out in &outcomes {
            let gid = out.gid;
            self.ensure_group_rt(gid);
            for fate in &out.fates {
                let jid = fate.job();
                let Some(&slot) = self.job_slot.get(&jid) else { continue };
                if self.jobs[slot].done {
                    continue;
                }
                self.interrupt(slot);
                let repinned = matches!(fate, MemberFate::Repinned { .. });
                match fate {
                    MemberFate::Repinned { roll_nodes, .. } => {
                        self.jobs[slot].roll_nodes = roll_nodes.clone();
                        self.group_rt[gid].set_roll_nodes(slot, roll_nodes.clone());
                        self.res.evictions += 1;
                    }
                    MemberFate::Spilled { decision, .. } => {
                        self.group_rt[gid].complete(slot);
                        self.respill(slot, decision);
                        self.res.spills += 1;
                    }
                }
                let to_gid = match fate {
                    MemberFate::Repinned { .. } => gid,
                    MemberFate::Spilled { decision, .. } => decision.group_id,
                };
                self.world_event(WorldEvent::Repair {
                    t: self.now,
                    job: jid,
                    gid,
                    to_gid,
                    repinned,
                });
                let params_b = self.jobs[slot].spec.params_b;
                let delay = repair::recovery_delay_s(
                    &self.cfg.switch,
                    &self.cfg.migration,
                    params_b,
                    repinned,
                );
                // Provenance (ISSUE 10): cap-shrink displacement is a
                // repair fate with no dead node — `usize::MAX` sentinel.
                if self.cfg.record_flight && self.cfg.record_decisions {
                    self.res.flight.push(Frame::Repair {
                        t: self.now,
                        gid,
                        node: usize::MAX,
                        job: jid,
                        to_gid,
                        repinned,
                        delay_s: delay,
                    });
                }
                let ep = {
                    let rt = &mut self.jobs[slot];
                    rt.recoveries += 1;
                    rt.recovery_s += delay;
                    rt.epoch
                };
                self.res.recovery_time_s += delay;
                self.push(self.now + delay, Ev::Recover(slot, ep));
            }
            if self.group_rt.get(gid).is_some() {
                self.drain_dispatch(gid);
            }
        }
        self.rate_changed();
        Some(outcomes)
    }
}

// ----------------------------------------------------------------------
// Snapshot / fork (ISSUE 9, DESIGN.md §17).
//
// A snapshot captures the simulator's FULL mutable state — job slab,
// event queue (with original seqs), orchestrator cores, scheduler
// groups + residency ledger, fault stream, RNG states, cost integrator,
// partial results — but NOT the immutable inputs (SimConfig, the trace's
// JobSpecs, PhaseModel): the caller re-supplies those on restore, and
// the snapshot only carries the two spec fields the engine mutates
// live (`cfg.intra` via set_intra_policy; `arrival_s` via submit's
// clamp). Restoring and draining is bit-identical to never having
// snapshotted (property-tested in rust/tests/prop_snapshot.rs).
// ----------------------------------------------------------------------

/// Captured mutable state of one job-slab slot. The spec itself is NOT
/// stored — restore resolves it by job id from the caller-supplied
/// trace (slab slot != trace index once jobs spill or arrive out of
/// order) and overrides `arrival_s` with the captured value.
#[derive(Clone, Debug)]
struct JobSnap {
    id: JobId,
    arrival_s: f64,
    group: usize,
    roll_nodes: Vec<usize>,
    train_gpus: usize,
    train_scale: f64,
    t_sync: f64,
    iter: usize,
    solo_s: f64,
    solo_est_iter_s: f64,
    init_s: f64,
    migrations: usize,
    rng: (u64, u64),
    cur_troll: f64,
    cur_ttrain: f64,
    cur_roll_end: f64,
    tail_penalty: f64,
    tail_frac: f64,
    done: bool,
    epoch: u32,
    phase: Option<PhaseKind>,
    phase_start_s: f64,
    cur_train_end: f64,
    iter_sampled: bool,
    iter_busy_gpu_s: f64,
    iter_wasted_gpu_s: f64,
    consolidated: bool,
    pending_tail: Option<(f64, usize)>,
    recoveries: usize,
    recovery_s: f64,
}

impl JobSnap {
    fn capture(rt: &JobRt) -> JobSnap {
        JobSnap {
            id: rt.spec.id,
            arrival_s: rt.spec.arrival_s,
            group: rt.group,
            roll_nodes: rt.roll_nodes.clone(),
            train_gpus: rt.train_gpus,
            train_scale: rt.train_scale,
            t_sync: rt.t_sync,
            iter: rt.iter,
            solo_s: rt.solo_s,
            solo_est_iter_s: rt.solo_est_iter_s,
            init_s: rt.init_s,
            migrations: rt.migrations,
            rng: rt.rng.to_parts(),
            cur_troll: rt.cur_troll,
            cur_ttrain: rt.cur_ttrain,
            cur_roll_end: rt.cur_roll_end,
            tail_penalty: rt.tail_penalty,
            tail_frac: rt.tail_frac,
            done: rt.done,
            epoch: rt.epoch,
            phase: rt.phase,
            phase_start_s: rt.phase_start_s,
            cur_train_end: rt.cur_train_end,
            iter_sampled: rt.iter_sampled,
            iter_busy_gpu_s: rt.iter_busy_gpu_s,
            iter_wasted_gpu_s: rt.iter_wasted_gpu_s,
            consolidated: rt.consolidated,
            pending_tail: rt.pending_tail,
            recoveries: rt.recoveries,
            recovery_s: rt.recovery_s,
        }
    }

    /// Rebuild the slab entry around the caller-resolved spec (its
    /// `arrival_s` already overridden with the captured value).
    fn revive(&self, spec: JobSpec) -> JobRt {
        JobRt {
            spec,
            group: self.group,
            roll_nodes: self.roll_nodes.clone(),
            train_gpus: self.train_gpus,
            train_scale: self.train_scale,
            t_sync: self.t_sync,
            iter: self.iter,
            solo_s: self.solo_s,
            solo_est_iter_s: self.solo_est_iter_s,
            init_s: self.init_s,
            migrations: self.migrations,
            rng: Rng::from_parts(self.rng.0, self.rng.1),
            cur_troll: self.cur_troll,
            cur_ttrain: self.cur_ttrain,
            cur_roll_end: self.cur_roll_end,
            tail_penalty: self.tail_penalty,
            tail_frac: self.tail_frac,
            done: self.done,
            epoch: self.epoch,
            phase: self.phase,
            phase_start_s: self.phase_start_s,
            cur_train_end: self.cur_train_end,
            iter_sampled: self.iter_sampled,
            iter_busy_gpu_s: self.iter_busy_gpu_s,
            iter_wasted_gpu_s: self.iter_wasted_gpu_s,
            consolidated: self.consolidated,
            pending_tail: self.pending_tail,
            recoveries: self.recoveries,
            recovery_s: self.recovery_s,
        }
    }
}

/// A full-state checkpoint of a [`Simulator<InterGroupScheduler>`]
/// (ISSUE 9, DESIGN.md §17). Opaque by design: its fields are private
/// (several wrap private engine types), it is produced by
/// [`Simulator::snapshot`] / [`Simulator::fork_at`], consumed by
/// [`Simulator::restore`], and serialized deterministically via
/// [`Self::to_bytes`] / [`Self::from_bytes`] (all map-shaped state is
/// captured in sorted order, f64s as exact bits — same bytes for the
/// same state, byte-for-byte).
#[derive(Clone, Debug)]
pub struct SimSnapshot {
    now: f64,
    seq: u64,
    /// `cfg.intra` is live-mutated (`set_intra_policy`), so the snapshot
    /// carries it and restore overrides the caller cfg's value.
    intra: IntraPolicyKind,
    /// Per trace index: `Some((id, arrival_s))` while the arrival has
    /// not fired (submit may have clamped `arrival_s`; the id gates the
    /// caller-supplied spec at restore).
    trace_pending: Vec<Option<(JobId, f64)>>,
    /// The pending-event set in pop order, with ORIGINAL seqs — restore
    /// re-pushes them verbatim, and pop order is a total order on
    /// `(t, seq)`, so the restored queue pops identically (even across
    /// `EventQueueKind`s).
    events: Vec<(f64, u64, Ev)>,
    jobs: Vec<JobSnap>,
    /// Sorted by job id (HashMap mirror, deterministic serialization).
    job_slot: Vec<(JobId, usize)>,
    faults: Option<(((u64, u64), f64, usize), usize, Option<FaultEvent>)>,
    /// Sorted by (gid, node) (HashMap mirror).
    node_down_until: Vec<(usize, usize, f64)>,
    orchs: Vec<OrchSnapshot>,
    accts: Vec<GroupAcct>,
    members: Vec<Vec<usize>>,
    high_water: f64,
    /// The partial result as of the snapshot (pre-finalize: busy
    /// integrals still live in `accts`).
    res: SimResult,
    open_world: bool,
    last_rate_change: f64,
    cur_rate_per_h: f64,
    cur_roll_gpus: usize,
    cur_train_gpus: usize,
    emit_events: bool,
    world_events: Vec<WorldEvent>,
    sched: SchedSnapshot,
}

impl SimSnapshot {
    /// Virtual time the snapshot was taken at.
    pub fn t(&self) -> f64 {
        self.now
    }

    /// Live (admitted, not yet settled) jobs in the captured slab.
    pub fn live_jobs(&self) -> usize {
        self.jobs.iter().filter(|j| !j.done).count()
    }

    /// Pending events in the captured queue.
    pub fn pending_events(&self) -> usize {
        self.events.len()
    }

    /// Serialize to a deterministic byte image (DESIGN.md §17). Fixed
    /// 8-byte little-endian words: f64s as exact IEEE bits, usizes as
    /// u64, enums as explicit tags, map-shaped state already sorted at
    /// capture — the same state always yields the same bytes, so two
    /// snapshots are bit-identical iff their byte images are.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Enc::default();
        e.buf.extend_from_slice(SNAP_MAGIC);
        e.f64(self.now);
        e.u64(self.seq);
        e.u64(intra_tag(self.intra));
        e.usize(self.trace_pending.len());
        for p in &self.trace_pending {
            match p {
                None => e.bool(false),
                Some((id, arr)) => {
                    e.bool(true);
                    e.usize(*id);
                    e.f64(*arr);
                }
            }
        }
        e.usize(self.events.len());
        for &(t, seq, ev) in &self.events {
            e.f64(t);
            e.u64(seq);
            enc_ev(&mut e, ev);
        }
        e.usize(self.jobs.len());
        for j in &self.jobs {
            enc_job(&mut e, j);
        }
        e.usize(self.job_slot.len());
        for &(id, slot) in &self.job_slot {
            e.usize(id);
            e.usize(slot);
        }
        match &self.faults {
            None => e.bool(false),
            Some(((rng, t, emitted), handed, pending)) => {
                e.bool(true);
                e.u64(rng.0);
                e.u64(rng.1);
                e.f64(*t);
                e.usize(*emitted);
                e.usize(*handed);
                match pending {
                    None => e.bool(false),
                    Some(f) => {
                        e.bool(true);
                        enc_fault(&mut e, f);
                    }
                }
            }
        }
        e.usize(self.node_down_until.len());
        for &(g, n, t) in &self.node_down_until {
            e.usize(g);
            e.usize(n);
            e.f64(t);
        }
        e.usize(self.orchs.len());
        for o in &self.orchs {
            enc_orch(&mut e, o);
        }
        e.usize(self.accts.len());
        for a in &self.accts {
            enc_acct(&mut e, a);
        }
        e.usize(self.members.len());
        for m in &self.members {
            e.usizes(m);
        }
        e.f64(self.high_water);
        enc_result(&mut e, &self.res);
        e.bool(self.open_world);
        e.f64(self.last_rate_change);
        e.f64(self.cur_rate_per_h);
        e.usize(self.cur_roll_gpus);
        e.usize(self.cur_train_gpus);
        e.bool(self.emit_events);
        e.usize(self.world_events.len());
        for w in &self.world_events {
            enc_world(&mut e, w);
        }
        enc_sched(&mut e, &self.sched);
        e.buf
    }

    /// Decode a [`Self::to_bytes`] image. Errors (never panics) on a bad
    /// magic, truncation, unknown enum tags, or trailing bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<SimSnapshot, String> {
        if bytes.len() < SNAP_MAGIC.len() || &bytes[..SNAP_MAGIC.len()] != SNAP_MAGIC {
            return Err("snapshot corrupt: bad magic (not an RMSNAP01 image)".to_string());
        }
        let mut d = Dec { buf: bytes, pos: SNAP_MAGIC.len() };
        let now = d.f64()?;
        let seq = d.u64()?;
        let intra = intra_from(d.u64()?)?;
        let n = d.len()?;
        let mut trace_pending = Vec::with_capacity(n);
        for _ in 0..n {
            trace_pending.push(if d.bool()? {
                Some((d.usize()?, d.f64()?))
            } else {
                None
            });
        }
        let n = d.len()?;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            events.push((d.f64()?, d.u64()?, dec_ev(&mut d)?));
        }
        let n = d.len()?;
        let mut jobs = Vec::with_capacity(n);
        for _ in 0..n {
            jobs.push(dec_job(&mut d)?);
        }
        let n = d.len()?;
        let mut job_slot = Vec::with_capacity(n);
        for _ in 0..n {
            job_slot.push((d.usize()?, d.usize()?));
        }
        let faults = if d.bool()? {
            let rng = (d.u64()?, d.u64()?);
            let t = d.f64()?;
            let emitted = d.usize()?;
            let handed = d.usize()?;
            let pending = if d.bool()? { Some(dec_fault(&mut d)?) } else { None };
            Some(((rng, t, emitted), handed, pending))
        } else {
            None
        };
        let n = d.len()?;
        let mut node_down_until = Vec::with_capacity(n);
        for _ in 0..n {
            node_down_until.push((d.usize()?, d.usize()?, d.f64()?));
        }
        let n = d.len()?;
        let mut orchs = Vec::with_capacity(n);
        for _ in 0..n {
            orchs.push(dec_orch(&mut d)?);
        }
        let n = d.len()?;
        let mut accts = Vec::with_capacity(n);
        for _ in 0..n {
            accts.push(dec_acct(&mut d)?);
        }
        let n = d.len()?;
        let mut members = Vec::with_capacity(n);
        for _ in 0..n {
            members.push(d.usizes()?);
        }
        let high_water = d.f64()?;
        let res = dec_result(&mut d)?;
        let open_world = d.bool()?;
        let last_rate_change = d.f64()?;
        let cur_rate_per_h = d.f64()?;
        let cur_roll_gpus = d.usize()?;
        let cur_train_gpus = d.usize()?;
        let emit_events = d.bool()?;
        let n = d.len()?;
        let mut world_events = Vec::with_capacity(n);
        for _ in 0..n {
            world_events.push(dec_world(&mut d)?);
        }
        let sched = dec_sched(&mut d)?;
        if d.pos != bytes.len() {
            return Err(format!(
                "snapshot corrupt: {} trailing bytes",
                bytes.len() - d.pos
            ));
        }
        Ok(SimSnapshot {
            now,
            seq,
            intra,
            trace_pending,
            events,
            jobs,
            job_slot,
            faults,
            node_down_until,
            orchs,
            accts,
            members,
            high_water,
            res,
            open_world,
            last_rate_change,
            cur_rate_per_h,
            cur_roll_gpus,
            cur_train_gpus,
            emit_events,
            world_events,
            sched,
        })
    }
}

const SNAP_MAGIC: &[u8; 8] = b"RMSNAP01";

/// Word-oriented encoder for [`SimSnapshot::to_bytes`]: every primitive
/// is one little-endian u64 (f64s as exact bits), so the layout has no
/// alignment or platform-width dependence.
#[derive(Default)]
pub(crate) struct Enc {
    pub(crate) buf: Vec<u8>,
}

impl Enc {
    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.u64(v as u64);
    }
    pub(crate) fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    pub(crate) fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn bool(&mut self, v: bool) {
        self.u64(v as u64);
    }
    fn opt_usize(&mut self, v: Option<usize>) {
        match v {
            None => self.bool(false),
            Some(x) => {
                self.bool(true);
                self.usize(x);
            }
        }
    }
    fn usizes(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }
    fn f64s(&mut self, v: &[f64]) {
        self.usize(v.len());
        for &x in v {
            self.f64(x);
        }
    }
}

/// Cursor-based decoder mirroring [`Enc`]; every read is bounds-checked
/// and length prefixes are capped against the remaining payload so a
/// corrupt image errors instead of allocating wildly.
pub(crate) struct Dec<'a> {
    pub(crate) buf: &'a [u8],
    pub(crate) pos: usize,
}

impl Dec<'_> {
    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        let end = self.pos + 8;
        let b = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| "snapshot corrupt: truncated".to_string())?;
        self.pos = end;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(self.u64()? as u32)
    }
    fn usize(&mut self) -> Result<usize, String> {
        Ok(self.u64()? as usize)
    }
    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn bool(&mut self) -> Result<bool, String> {
        Ok(self.u64()? != 0)
    }
    /// Length prefix: each counted element occupies at least one word, so
    /// a count exceeding the remaining words is definitely corrupt.
    fn len(&mut self) -> Result<usize, String> {
        let n = self.usize()?;
        if n > (self.buf.len() - self.pos) / 8 {
            return Err(format!("snapshot corrupt: length {n} exceeds remaining payload"));
        }
        Ok(n)
    }
    fn opt_usize(&mut self) -> Result<Option<usize>, String> {
        Ok(if self.bool()? { Some(self.usize()?) } else { None })
    }
    fn usizes(&mut self) -> Result<Vec<usize>, String> {
        let n = self.len()?;
        (0..n).map(|_| self.usize()).collect()
    }
    fn f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.len()?;
        (0..n).map(|_| self.f64()).collect()
    }
}

fn intra_tag(k: IntraPolicyKind) -> u64 {
    match k {
        IntraPolicyKind::WorkConservingFifo => 0,
        IntraPolicyKind::StrictRoundRobin => 1,
        IntraPolicyKind::SloSlackPriority => 2,
    }
}

fn intra_from(tag: u64) -> Result<IntraPolicyKind, String> {
    Ok(match tag {
        0 => IntraPolicyKind::WorkConservingFifo,
        1 => IntraPolicyKind::StrictRoundRobin,
        2 => IntraPolicyKind::SloSlackPriority,
        t => return Err(format!("snapshot corrupt: unknown intra-policy tag {t}")),
    })
}

fn phase_kind_tag(k: PhaseKind) -> u64 {
    match k {
        PhaseKind::Init => 0,
        PhaseKind::Rollout => 1,
        PhaseKind::Train => 2,
        PhaseKind::Sync => 3,
    }
}

fn phase_kind_from(tag: u64) -> Result<PhaseKind, String> {
    Ok(match tag {
        0 => PhaseKind::Init,
        1 => PhaseKind::Rollout,
        2 => PhaseKind::Train,
        3 => PhaseKind::Sync,
        t => return Err(format!("snapshot corrupt: unknown phase-kind tag {t}")),
    })
}

/// Placement-kind tag shared by the `Frame::Placement` provenance frame
/// and the trace codec (0 = direct pack, 1 = rollout scale, 2 =
/// isolated). `RolloutScale`'s node count is carried by the decision's
/// node list, not the tag.
pub(crate) fn placement_kind_tag(k: &PlacementKind) -> u8 {
    match k {
        PlacementKind::DirectPack => 0,
        PlacementKind::RolloutScale { .. } => 1,
        PlacementKind::Isolated => 2,
    }
}

fn core_tag(c: CorePhase) -> u64 {
    match c {
        CorePhase::Rollout => 0,
        CorePhase::Train => 1,
    }
}

fn core_from(tag: u64) -> Result<CorePhase, String> {
    Ok(match tag {
        0 => CorePhase::Rollout,
        1 => CorePhase::Train,
        t => return Err(format!("snapshot corrupt: unknown core-phase tag {t}")),
    })
}

fn enc_ev(e: &mut Enc, ev: Ev) {
    match ev {
        Ev::Arrival(i) => {
            e.u64(0);
            e.usize(i);
        }
        Ev::TailFree(slot, kept, epoch) => {
            e.u64(1);
            e.usize(slot);
            e.usize(kept);
            e.u32(epoch);
        }
        Ev::PhaseDone(slot, kind, iter, epoch) => {
            e.u64(2);
            e.usize(slot);
            e.u64(phase_kind_tag(kind));
            e.usize(iter);
            e.u32(epoch);
        }
        Ev::Fault(i) => {
            e.u64(3);
            e.usize(i);
        }
        Ev::FaultRecover(g, n) => {
            e.u64(4);
            e.usize(g);
            e.usize(n);
        }
        Ev::Recover(slot, epoch) => {
            e.u64(5);
            e.usize(slot);
            e.u32(epoch);
        }
    }
}

fn dec_ev(d: &mut Dec) -> Result<Ev, String> {
    Ok(match d.u64()? {
        0 => Ev::Arrival(d.usize()?),
        1 => Ev::TailFree(d.usize()?, d.usize()?, d.u32()?),
        2 => Ev::PhaseDone(d.usize()?, phase_kind_from(d.u64()?)?, d.usize()?, d.u32()?),
        3 => Ev::Fault(d.usize()?),
        4 => Ev::FaultRecover(d.usize()?, d.usize()?),
        5 => Ev::Recover(d.usize()?, d.u32()?),
        t => return Err(format!("snapshot corrupt: unknown event tag {t}")),
    })
}

fn enc_world(e: &mut Enc, w: &WorldEvent) {
    match *w {
        WorldEvent::Done { t, job } => {
            e.u64(0);
            e.f64(t);
            e.usize(job);
        }
        WorldEvent::Crash { t, gid, node } => {
            e.u64(1);
            e.f64(t);
            e.usize(gid);
            e.usize(node);
        }
        WorldEvent::Straggle { t, gid, node, factor } => {
            e.u64(2);
            e.f64(t);
            e.usize(gid);
            e.usize(node);
            e.f64(factor);
        }
        WorldEvent::Repair { t, job, gid, to_gid, repinned } => {
            e.u64(3);
            e.f64(t);
            e.usize(job);
            e.usize(gid);
            e.usize(to_gid);
            e.bool(repinned);
        }
        WorldEvent::NodeUp { t, gid, node } => {
            e.u64(4);
            e.f64(t);
            e.usize(gid);
            e.usize(node);
        }
    }
}

fn dec_world(d: &mut Dec) -> Result<WorldEvent, String> {
    Ok(match d.u64()? {
        0 => WorldEvent::Done { t: d.f64()?, job: d.usize()? },
        1 => WorldEvent::Crash { t: d.f64()?, gid: d.usize()?, node: d.usize()? },
        2 => WorldEvent::Straggle {
            t: d.f64()?,
            gid: d.usize()?,
            node: d.usize()?,
            factor: d.f64()?,
        },
        3 => WorldEvent::Repair {
            t: d.f64()?,
            job: d.usize()?,
            gid: d.usize()?,
            to_gid: d.usize()?,
            repinned: d.bool()?,
        },
        4 => WorldEvent::NodeUp { t: d.f64()?, gid: d.usize()?, node: d.usize()? },
        t => return Err(format!("snapshot corrupt: unknown world-event tag {t}")),
    })
}

fn enc_fault(e: &mut Enc, f: &FaultEvent) {
    e.f64(f.t);
    e.u64(f.victim);
    match f.kind {
        FaultKind::NodeCrash { repair_s } => {
            e.u64(0);
            e.f64(repair_s);
        }
        FaultKind::Straggler { factor } => {
            e.u64(1);
            e.f64(factor);
        }
    }
}

fn dec_fault(d: &mut Dec) -> Result<FaultEvent, String> {
    let t = d.f64()?;
    let victim = d.u64()?;
    let kind = match d.u64()? {
        0 => FaultKind::NodeCrash { repair_s: d.f64()? },
        1 => FaultKind::Straggler { factor: d.f64()? },
        t => return Err(format!("snapshot corrupt: unknown fault-kind tag {t}")),
    };
    Ok(FaultEvent { t, victim, kind })
}

fn enc_rec(e: &mut Enc, r: &PhaseRecord) {
    e.usize(r.job);
    e.usize(r.group);
    e.u64(phase_kind_tag(r.kind));
    e.usize(r.iter);
    e.f64(r.start);
    e.f64(r.end);
    e.usizes(&r.roll_nodes);
}

fn dec_rec(d: &mut Dec) -> Result<PhaseRecord, String> {
    Ok(PhaseRecord {
        job: d.usize()?,
        group: d.usize()?,
        kind: phase_kind_from(d.u64()?)?,
        iter: d.usize()?,
        start: d.f64()?,
        end: d.f64()?,
        roll_nodes: d.usizes()?,
    })
}

pub(crate) fn enc_frame(e: &mut Enc, f: &Frame) {
    match f {
        Frame::Phase(r) => {
            e.u64(0);
            enc_rec(e, r);
        }
        Frame::World(w) => {
            e.u64(1);
            enc_world(e, w);
        }
        Frame::Util { t, gid, roll_busy_gpu_s, train_busy_gpu_s } => {
            e.u64(2);
            e.f64(*t);
            e.usize(*gid);
            e.f64(*roll_busy_gpu_s);
            e.f64(*train_busy_gpu_s);
        }
        Frame::SloSlack { t, job, iter, slack_s } => {
            e.u64(3);
            e.f64(*t);
            e.usize(*job);
            e.usize(*iter);
            e.f64(*slack_s);
        }
        Frame::Placement { t, job, gid, kind_tag, marginal_cost, considered } => {
            e.u64(4);
            e.f64(*t);
            e.usize(*job);
            e.usize(*gid);
            e.u64(*kind_tag as u64);
            e.f64(*marginal_cost);
            e.usize(considered.len());
            for &(g, delta) in considered {
                e.usize(g);
                e.f64(delta);
            }
        }
        Frame::Repair { t, gid, node, job, to_gid, repinned, delay_s } => {
            e.u64(5);
            e.f64(*t);
            e.usize(*gid);
            e.usize(*node);
            e.usize(*job);
            e.usize(*to_gid);
            e.bool(*repinned);
            e.f64(*delay_s);
        }
        Frame::Dispatch { t, gid, job, kind, policy, queue_depth } => {
            e.u64(6);
            e.f64(*t);
            e.usize(*gid);
            e.usize(*job);
            e.u64(*kind as u64);
            e.u64(*policy as u64);
            e.usize(*queue_depth);
        }
    }
}

pub(crate) fn dec_frame(d: &mut Dec) -> Result<Frame, String> {
    Ok(match d.u64()? {
        0 => Frame::Phase(dec_rec(d)?),
        1 => Frame::World(dec_world(d)?),
        2 => Frame::Util {
            t: d.f64()?,
            gid: d.usize()?,
            roll_busy_gpu_s: d.f64()?,
            train_busy_gpu_s: d.f64()?,
        },
        3 => Frame::SloSlack {
            t: d.f64()?,
            job: d.usize()?,
            iter: d.usize()?,
            slack_s: d.f64()?,
        },
        4 => {
            let t = d.f64()?;
            let job = d.usize()?;
            let gid = d.usize()?;
            let kind_tag = match d.u64()? {
                k @ 0..=2 => k as u8,
                k => return Err(format!("snapshot corrupt: unknown placement-kind tag {k}")),
            };
            let marginal_cost = d.f64()?;
            let n = d.len()?;
            let considered = (0..n)
                .map(|_| Ok((d.usize()?, d.f64()?)))
                .collect::<Result<Vec<_>, String>>()?;
            Frame::Placement { t, job, gid, kind_tag, marginal_cost, considered }
        }
        5 => Frame::Repair {
            t: d.f64()?,
            gid: d.usize()?,
            node: d.usize()?,
            job: d.usize()?,
            to_gid: d.usize()?,
            repinned: d.bool()?,
            delay_s: d.f64()?,
        },
        6 => {
            let t = d.f64()?;
            let gid = d.usize()?;
            let job = d.usize()?;
            let kind = match d.u64()? {
                k @ 0..=1 => k as u8,
                k => return Err(format!("snapshot corrupt: unknown dispatch-kind tag {k}")),
            };
            let policy = match d.u64()? {
                p @ 0..=2 => p as u8,
                p => return Err(format!("snapshot corrupt: unknown dispatch-policy tag {p}")),
            };
            Frame::Dispatch { t, gid, job, kind, policy, queue_depth: d.usize()? }
        }
        t => return Err(format!("snapshot corrupt: unknown frame tag {t}")),
    })
}

fn enc_job(e: &mut Enc, j: &JobSnap) {
    e.usize(j.id);
    e.f64(j.arrival_s);
    e.usize(j.group);
    e.usizes(&j.roll_nodes);
    e.usize(j.train_gpus);
    e.f64(j.train_scale);
    e.f64(j.t_sync);
    e.usize(j.iter);
    e.f64(j.solo_s);
    e.f64(j.solo_est_iter_s);
    e.f64(j.init_s);
    e.usize(j.migrations);
    e.u64(j.rng.0);
    e.u64(j.rng.1);
    e.f64(j.cur_troll);
    e.f64(j.cur_ttrain);
    e.f64(j.cur_roll_end);
    e.f64(j.tail_penalty);
    e.f64(j.tail_frac);
    e.bool(j.done);
    e.u32(j.epoch);
    match j.phase {
        None => e.bool(false),
        Some(k) => {
            e.bool(true);
            e.u64(phase_kind_tag(k));
        }
    }
    e.f64(j.phase_start_s);
    e.f64(j.cur_train_end);
    e.bool(j.iter_sampled);
    e.f64(j.iter_busy_gpu_s);
    e.f64(j.iter_wasted_gpu_s);
    e.bool(j.consolidated);
    match j.pending_tail {
        None => e.bool(false),
        Some((t, kept)) => {
            e.bool(true);
            e.f64(t);
            e.usize(kept);
        }
    }
    e.usize(j.recoveries);
    e.f64(j.recovery_s);
}

fn dec_job(d: &mut Dec) -> Result<JobSnap, String> {
    Ok(JobSnap {
        id: d.usize()?,
        arrival_s: d.f64()?,
        group: d.usize()?,
        roll_nodes: d.usizes()?,
        train_gpus: d.usize()?,
        train_scale: d.f64()?,
        t_sync: d.f64()?,
        iter: d.usize()?,
        solo_s: d.f64()?,
        solo_est_iter_s: d.f64()?,
        init_s: d.f64()?,
        migrations: d.usize()?,
        rng: (d.u64()?, d.u64()?),
        cur_troll: d.f64()?,
        cur_ttrain: d.f64()?,
        cur_roll_end: d.f64()?,
        tail_penalty: d.f64()?,
        tail_frac: d.f64()?,
        done: d.bool()?,
        epoch: d.u32()?,
        phase: if d.bool()? { Some(phase_kind_from(d.u64()?)?) } else { None },
        phase_start_s: d.f64()?,
        cur_train_end: d.f64()?,
        iter_sampled: d.bool()?,
        iter_busy_gpu_s: d.f64()?,
        iter_wasted_gpu_s: d.f64()?,
        consolidated: d.bool()?,
        pending_tail: if d.bool()? { Some((d.f64()?, d.usize()?)) } else { None },
        recoveries: d.usize()?,
        recovery_s: d.f64()?,
    })
}

fn enc_orch(e: &mut Enc, o: &OrchSnapshot) {
    e.usize(o.members.len());
    for (slot, job, nodes, slack) in &o.members {
        e.usize(*slot);
        e.usize(*job);
        e.usizes(nodes);
        e.f64(*slack);
    }
    e.usize(o.roll_busy.len());
    for &s in &o.roll_busy {
        e.opt_usize(s);
    }
    e.opt_usize(o.train_busy);
    e.usize(o.queue.len());
    for &(slot, cp) in &o.queue {
        e.usize(slot);
        e.u64(core_tag(cp));
    }
    match &o.rotation {
        None => e.bool(false),
        Some((order, cursor)) => {
            e.bool(true);
            e.usizes(order);
            e.usize(*cursor);
        }
    }
}

fn dec_orch(d: &mut Dec) -> Result<OrchSnapshot, String> {
    let n = d.len()?;
    let mut members = Vec::with_capacity(n);
    for _ in 0..n {
        members.push((d.usize()?, d.usize()?, d.usizes()?, d.f64()?));
    }
    let n = d.len()?;
    let mut roll_busy = Vec::with_capacity(n);
    for _ in 0..n {
        roll_busy.push(d.opt_usize()?);
    }
    let train_busy = d.opt_usize()?;
    let n = d.len()?;
    let mut queue = Vec::with_capacity(n);
    for _ in 0..n {
        queue.push((d.usize()?, core_from(d.u64()?)?));
    }
    let rotation = if d.bool()? { Some((d.usizes()?, d.usize()?)) } else { None };
    Ok(OrchSnapshot { members, roll_busy, train_busy, queue, rotation })
}

fn enc_acct(e: &mut Enc, a: &GroupAcct) {
    e.f64(a.roll_busy_gpu_s);
    e.f64(a.train_busy_gpu_s);
    e.bool(a.train_touched);
    e.f64s(&a.node_busy_gpu_s);
    e.usize(a.events);
}

fn dec_acct(d: &mut Dec) -> Result<GroupAcct, String> {
    Ok(GroupAcct {
        roll_busy_gpu_s: d.f64()?,
        train_busy_gpu_s: d.f64()?,
        train_touched: d.bool()?,
        node_busy_gpu_s: d.f64s()?,
        events: d.usize()?,
    })
}

fn enc_result(e: &mut Enc, r: &SimResult) {
    e.usize(r.records.len());
    for rec in &r.records {
        enc_rec(e, rec);
    }
    let mut ids: Vec<JobId> = r.outcomes.keys().copied().collect();
    ids.sort_unstable();
    e.usize(ids.len());
    for id in ids {
        let o = &r.outcomes[&id];
        e.usize(id);
        e.f64(o.arrival_s);
        e.f64(o.finish_s);
        e.f64(o.solo_actual_s);
        e.f64(o.solo_est_s);
        e.f64(o.slo);
        e.usize(o.iters);
        e.usize(o.migrations);
        e.usize(o.recoveries);
        e.f64(o.recovery_s);
    }
    e.f64(r.cost_usd);
    e.f64(r.avg_cost_per_hour);
    e.usize(r.peak_roll_gpus);
    e.usize(r.peak_train_gpus);
    e.f64(r.roll_busy_gpu_s);
    e.f64(r.train_busy_gpu_s);
    e.f64(r.roll_prov_gpu_s);
    e.f64(r.train_prov_gpu_s);
    e.f64(r.makespan_s);
    e.usize(r.usage_curve.len());
    for &(t, rg, tg) in &r.usage_curve {
        e.f64(t);
        e.usize(rg);
        e.usize(tg);
    }
    e.usize(r.roll_node_busy_gpu_s.len());
    for v in &r.roll_node_busy_gpu_s {
        e.f64s(v);
    }
    e.f64s(&r.train_group_busy_gpu_s);
    e.usize(r.events_processed);
    e.usize(r.crashes);
    e.usize(r.stragglers);
    e.usize(r.evictions);
    e.usize(r.spills);
    e.f64(r.recovery_time_s);
    e.f64(r.wasted_gpu_s);
    e.usize(r.cancelled);
    e.usize(r.flight.len());
    for f in r.flight.frames() {
        enc_frame(e, f);
    }
}

fn dec_result(d: &mut Dec) -> Result<SimResult, String> {
    let n = d.len()?;
    let mut records = Vec::with_capacity(n);
    for _ in 0..n {
        records.push(dec_rec(d)?);
    }
    let n = d.len()?;
    let mut outcomes = HashMap::with_capacity(n);
    for _ in 0..n {
        let id = d.usize()?;
        outcomes.insert(
            id,
            JobOutcome {
                arrival_s: d.f64()?,
                finish_s: d.f64()?,
                solo_actual_s: d.f64()?,
                solo_est_s: d.f64()?,
                slo: d.f64()?,
                iters: d.usize()?,
                migrations: d.usize()?,
                recoveries: d.usize()?,
                recovery_s: d.f64()?,
            },
        );
    }
    let cost_usd = d.f64()?;
    let avg_cost_per_hour = d.f64()?;
    let peak_roll_gpus = d.usize()?;
    let peak_train_gpus = d.usize()?;
    let roll_busy_gpu_s = d.f64()?;
    let train_busy_gpu_s = d.f64()?;
    let roll_prov_gpu_s = d.f64()?;
    let train_prov_gpu_s = d.f64()?;
    let makespan_s = d.f64()?;
    let n = d.len()?;
    let mut usage_curve = Vec::with_capacity(n);
    for _ in 0..n {
        usage_curve.push((d.f64()?, d.usize()?, d.usize()?));
    }
    let n = d.len()?;
    let mut roll_node_busy_gpu_s = Vec::with_capacity(n);
    for _ in 0..n {
        roll_node_busy_gpu_s.push(d.f64s()?);
    }
    let train_group_busy_gpu_s = d.f64s()?;
    let events_processed = d.usize()?;
    let crashes = d.usize()?;
    let stragglers = d.usize()?;
    let evictions = d.usize()?;
    let spills = d.usize()?;
    let recovery_time_s = d.f64()?;
    let wasted_gpu_s = d.f64()?;
    let cancelled = d.usize()?;
    let n = d.len()?;
    let mut flight = FlightRecorder::default();
    for _ in 0..n {
        flight.push(dec_frame(d)?);
    }
    Ok(SimResult {
        records,
        outcomes,
        cost_usd,
        avg_cost_per_hour,
        peak_roll_gpus,
        peak_train_gpus,
        roll_busy_gpu_s,
        train_busy_gpu_s,
        roll_prov_gpu_s,
        train_prov_gpu_s,
        makespan_s,
        usage_curve,
        roll_node_busy_gpu_s,
        train_group_busy_gpu_s,
        events_processed,
        crashes,
        stragglers,
        evictions,
        spills,
        recovery_time_s,
        wasted_gpu_s,
        cancelled,
        flight,
    })
}

fn enc_sched(e: &mut Enc, s: &SchedSnapshot) {
    e.usize(s.groups.len());
    for (id, nr, nt, members) in &s.groups {
        e.usize(*id);
        e.usize(*nr);
        e.usize(*nt);
        e.usize(members.len());
        for (job, nodes) in members {
            e.usize(*job);
            e.usizes(nodes);
        }
    }
    e.usize(s.next_group_id);
    e.opt_usize(s.max_group_size);
    e.usize(s.shards);
    e.usize(s.ledger.len());
    for (node, bits, pins) in &s.ledger {
        e.usize(*node);
        e.u64(*bits);
        e.usize(pins.len());
        for &(job, b) in pins {
            e.usize(job);
            e.u64(b);
        }
    }
    e.u64(s.ledger_capacity_bits);
}

fn dec_sched(d: &mut Dec) -> Result<SchedSnapshot, String> {
    let n = d.len()?;
    let mut groups = Vec::with_capacity(n);
    for _ in 0..n {
        let id = d.usize()?;
        let nr = d.usize()?;
        let nt = d.usize()?;
        let m = d.len()?;
        let mut members = Vec::with_capacity(m);
        for _ in 0..m {
            members.push((d.usize()?, d.usizes()?));
        }
        groups.push((id, nr, nt, members));
    }
    let next_group_id = d.usize()?;
    let max_group_size = d.opt_usize()?;
    let shards = d.usize()?;
    let n = d.len()?;
    let mut ledger = Vec::with_capacity(n);
    for _ in 0..n {
        let node = d.usize()?;
        let bits = d.u64()?;
        let m = d.len()?;
        let mut pins = Vec::with_capacity(m);
        for _ in 0..m {
            pins.push((d.usize()?, d.u64()?));
        }
        ledger.push((node, bits, pins));
    }
    let ledger_capacity_bits = d.u64()?;
    Ok(SchedSnapshot {
        groups,
        next_group_id,
        max_group_size,
        shards,
        ledger,
        ledger_capacity_bits,
    })
}

impl Simulator<InterGroupScheduler> {
    /// Capture the full mutable state (ISSUE 9). Non-destructive: the
    /// event queue is drained from a clone. Must be taken BEFORE
    /// `finalize` (i.e. before `run_to_end` returns) — a finalized
    /// simulator has folded and cleared its accumulators.
    pub fn snapshot(&self) -> SimSnapshot {
        let mut events = Vec::new();
        let mut q = self.events.clone();
        while let Some((t, seq, ev)) = q.pop_with_seq() {
            events.push((t, seq, ev));
        }
        let mut job_slot: Vec<(JobId, usize)> =
            self.job_slot.iter().map(|(&id, &slot)| (id, slot)).collect();
        job_slot.sort_unstable();
        let mut node_down_until: Vec<(usize, usize, f64)> =
            self.node_down_until.iter().map(|(&(g, n), &t)| (g, n, t)).collect();
        node_down_until.sort_unstable_by_key(|&(g, n, _)| (g, n));
        SimSnapshot {
            now: self.now,
            seq: self.seq,
            intra: self.cfg.intra,
            trace_pending: self
                .trace
                .iter()
                .map(|s| s.as_ref().map(|s| (s.id, s.arrival_s)))
                .collect(),
            events,
            jobs: self.jobs.iter().map(JobSnap::capture).collect(),
            job_slot,
            faults: self.faults_rt.as_ref().map(FaultStream::snapshot_parts),
            node_down_until,
            orchs: self.group_rt.iter().map(GroupOrchestrator::snapshot_state).collect(),
            accts: (0..self.accts.len())
                .map(|g| self.accts.get(g).cloned().unwrap_or_default())
                .collect(),
            members: self.members.clone(),
            high_water: self.high_water,
            res: self.res.clone(),
            open_world: self.open_world,
            last_rate_change: self.last_rate_change,
            cur_rate_per_h: self.cur_rate_per_h,
            cur_roll_gpus: self.cur_roll_gpus,
            cur_train_gpus: self.cur_train_gpus,
            emit_events: self.emit_events,
            world_events: self.world_events.clone(),
            sched: self.sched.snapshot_state(),
        }
    }

    /// Rebuild a simulator from a snapshot plus the run's immutable
    /// inputs: the `cfg` and `trace` the ORIGINAL run was built with
    /// (`cfg.intra` is overridden by the snapshot's live value; a
    /// pending job's `arrival_s` by its captured clamp). Draining the
    /// restored simulator is bit-identical to draining the original —
    /// what-if branches diverge AFTER restore via `set_intra_policy`,
    /// `reconfig_group_cap`, `inject_node_crash`, `submit`, ….
    ///
    /// Panics on mismatched inputs (wrong trace length/ids, missing
    /// specs, `cfg.faults` armed-ness differing from the snapshot's).
    pub fn restore(cfg: SimConfig, trace: &[JobSpec], snap: &SimSnapshot) -> Self {
        let mut cfg = cfg;
        cfg.intra = snap.intra;
        assert_eq!(
            trace.len(),
            snap.trace_pending.len(),
            "restore: trace length differs from the snapshot's"
        );
        let spec_by_id: HashMap<JobId, &JobSpec> = trace.iter().map(|s| (s.id, s)).collect();
        let arrival_of: HashMap<JobId, f64> =
            snap.jobs.iter().map(|j| (j.id, j.arrival_s)).collect();
        let resolve = |jid: JobId| -> JobSpec {
            let mut s = (*spec_by_id
                .get(&jid)
                .unwrap_or_else(|| panic!("restore: job {jid} missing from the supplied trace")))
            .clone();
            if let Some(&arr) = arrival_of.get(&jid) {
                s.arrival_s = arr;
            }
            s
        };
        let mut sched = InterGroupScheduler::from_snapshot_state(cfg.model, &snap.sched, resolve);
        sched.set_record_provenance(cfg.record_flight && cfg.record_decisions);
        let mut events = EventQueue::new(cfg.event_queue);
        for &(t, seq, ev) in &snap.events {
            events.push(t, seq, ev);
        }
        let trace_slots: Vec<Option<JobSpec>> = snap
            .trace_pending
            .iter()
            .enumerate()
            .map(|(i, p)| {
                p.map(|(id, arr)| {
                    assert_eq!(trace[i].id, id, "restore: trace[{i}] id mismatch");
                    let mut s = trace[i].clone();
                    s.arrival_s = arr;
                    s
                })
            })
            .collect();
        let jobs: Vec<JobRt> = snap
            .jobs
            .iter()
            .map(|j| {
                let mut spec = (*spec_by_id
                    .get(&j.id)
                    .unwrap_or_else(|| panic!("restore: job {} missing from the supplied trace", j.id)))
                .clone();
                spec.arrival_s = j.arrival_s;
                j.revive(spec)
            })
            .collect();
        let faults_rt = match (&cfg.faults, &snap.faults) {
            (Some(fc), Some((gen, handed, pending))) => {
                Some(FaultStream::from_parts(fc.clone(), *gen, *handed, *pending))
            }
            (None, None) => None,
            (Some(_), None) => panic!(
                "restore: cfg.faults is armed but the snapshot has no fault stream"
            ),
            (None, Some(_)) => panic!(
                "restore: the snapshot has an armed fault stream but cfg.faults is None"
            ),
        };
        let mut accts = AcctArena::new();
        for (gid, acct) in snap.accts.iter().enumerate() {
            accts.put(gid, acct.clone());
        }
        Simulator {
            cfg,
            sched,
            trace: trace_slots,
            events,
            seq: snap.seq,
            now: snap.now,
            jobs,
            job_slot: snap.job_slot.iter().copied().collect(),
            faults_rt,
            node_down_until: snap
                .node_down_until
                .iter()
                .map(|&(g, n, t)| ((g, n), t))
                .collect(),
            group_rt: snap
                .orchs
                .iter()
                .map(|o| GroupOrchestrator::from_snapshot_state(snap.intra, o))
                .collect(),
            accts,
            members: snap.members.clone(),
            high_water: snap.high_water,
            res: snap.res.clone(),
            open_world: snap.open_world,
            last_rate_change: snap.last_rate_change,
            cur_rate_per_h: snap.cur_rate_per_h,
            cur_roll_gpus: snap.cur_roll_gpus,
            cur_train_gpus: snap.cur_train_gpus,
            scratch_lengths: Vec::new(),
            emit_events: snap.emit_events,
            world_events: snap.world_events.clone(),
        }
    }

    /// Branch-from-t (ISSUE 9): run the prefix up to `t` (without
    /// advancing the clock past the last real event — [`Self::run_until`])
    /// and capture a checkpoint. N what-if branches then [`Self::restore`]
    /// the same snapshot, diverge (policy swap, reconfig, fault burst, new
    /// submissions), and drain — each bit-identical to a from-scratch run
    /// that applied the same divergence at `t`, at the cost of ONE shared
    /// prefix simulation instead of N.
    pub fn fork_at(&mut self, t: f64) -> SimSnapshot {
        self.run_until(t);
        self.snapshot()
    }
}

/// Run one sweep point on a worker's pooled simulator slab: rearm the
/// existing simulator via [`Simulator::reset_with_trace`] (bit-identical
/// to fresh construction — property-tested), or construct it on first
/// use. The one idiom every pooled sweep driver shares (ISSUE 4); the
/// fluid counterpart is [`crate::sim::fluid::run_pooled`].
pub fn run_pooled<S: GroupScheduler>(
    slab: &mut Option<Simulator<S>>,
    cfg: SimConfig,
    sched: S,
    trace: Vec<JobSpec>,
) -> SimResult {
    match slab {
        Some(sim) => sim.reset_with_trace(cfg, sched, trace),
        None => *slab = Some(Simulator::new(cfg, sched, trace)),
    }
    slab.as_mut().expect("slab populated").run_to_end()
}

/// Run a trace on the tier `cfg.fidelity` selects: the event-exact
/// engine or the fluid fast path (DESIGN.md §12).
pub fn run_sim<S: GroupScheduler>(cfg: SimConfig, sched: S, trace: Vec<JobSpec>) -> SimResult {
    match cfg.fidelity {
        Fidelity::Exact => Simulator::new(cfg, sched, trace).run(),
        Fidelity::Fluid => crate::sim::fluid::FluidSimulator::new(cfg, sched, trace).run(),
    }
}

/// Convenience: run a trace under RollMux with the given config (honors
/// `cfg.fidelity`).
pub fn run_rollmux(cfg: SimConfig, trace: Vec<JobSpec>) -> SimResult {
    let sched = InterGroupScheduler::new(cfg.model);
    run_sim(cfg, sched, trace)
}

/// Reference: H20/H800 GPU hour prices (for cross-checks in tests).
pub fn h20_h800_prices() -> (f64, f64) {
    (GpuKind::H20.spec().cost_per_hour, GpuKind::H800.spec().cost_per_hour)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn direct_job(id: JobId, t_roll: f64, t_train: f64, slo: f64, iters: usize, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            name: format!("j{id}"),
            arrival_s: arrival,
            n_iters: iters,
            slo,
            n_roll_gpus: 8,
            n_train_gpus: 8,
            params_b: 7.0,
            phases: PhaseSpec::Direct { t_roll, t_train, cv: 0.0 },
        }
    }

    fn cfg() -> SimConfig {
        SimConfig { record_gantt: true, ..Default::default() }
    }

    #[test]
    fn single_job_completes_all_iterations() {
        let trace = vec![direct_job(0, 100.0, 50.0, 2.0, 5, 0.0)];
        let res = run_rollmux(cfg(), trace);
        let o = &res.outcomes[&0];
        assert_eq!(o.iters, 5);
        assert!(o.slo_met(), "solo job must trivially meet its SLO: {}", o.slowdown());
        // Makespan ~ init + 5*(roll+train+sync+switches).
        assert!(res.makespan_s > 5.0 * 150.0);
        assert!(res.makespan_s < 5.0 * 150.0 * 1.5);
    }

    #[test]
    fn two_jobs_multiplex_cheaper_than_solo() {
        let trace = vec![
            direct_job(0, 100.0, 80.0, 2.0, 10, 0.0),
            // Slightly smaller so both rollouts fit the first job's cycle
            // on one node (the over-saturation guard is strict).
            direct_job(1, 80.0, 60.0, 2.0, 10, 0.0),
        ];
        let res = run_rollmux(cfg(), trace);
        assert_eq!(res.outcomes.len(), 2);
        assert!((res.slo_attainment() - 1.0).abs() < 1e-9, "SLOs met");
        // Both jobs shared one group: peak = 8 + 8 GPUs.
        assert_eq!(res.peak_roll_gpus, 8);
        assert_eq!(res.peak_train_gpus, 8);
        // Co-execution bubbles below solo bubbles.
        let (rb, tb) = res.bubble_fracs();
        assert!(rb < 0.55, "rollout bubble {rb}");
        assert!(tb < 0.65, "train bubble {tb}");
    }

    #[test]
    fn event_times_monotone_and_no_overlap() {
        let trace = vec![
            direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
            direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
            direct_job(2, 60.0, 40.0, 3.0, 6, 100.0),
        ];
        let res = run_rollmux(cfg(), trace);
        // Per (group, rollout-node): no two rollout phases overlap.
        let mut by_node: HashMap<(usize, usize), Vec<(f64, f64)>> = HashMap::new();
        let mut by_train: HashMap<usize, Vec<(f64, f64)>> = HashMap::new();
        for r in &res.records {
            match r.kind {
                PhaseKind::Rollout => {
                    for &n in &r.roll_nodes {
                        by_node.entry((r.group, n)).or_default().push((r.start, r.end));
                    }
                }
                PhaseKind::Train => by_train.entry(r.group).or_default().push((r.start, r.end)),
                _ => {}
            }
            assert!(r.end >= r.start);
        }
        // NOTE: migration intentionally lets the NEXT job start on freed
        // nodes while the tail finishes; disable migration for the strict
        // non-overlap check.
        let trace2 = vec![
            direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
            direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
        ];
        let mut c = cfg();
        c.migration.enabled = false;
        let res2 = run_rollmux(c, trace2);
        let mut by_node2: HashMap<(usize, usize), Vec<(f64, f64)>> = HashMap::new();
        for r in &res2.records {
            if r.kind == PhaseKind::Rollout {
                for &n in &r.roll_nodes {
                    by_node2.entry((r.group, n)).or_default().push((r.start, r.end));
                }
            }
        }
        for (_, mut spans) in by_node2 {
            spans.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in spans.windows(2) {
                assert!(w[1].0 >= w[0].1 - 1e-6, "overlap: {:?}", w);
            }
        }
        for (_, mut spans) in by_train {
            spans.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in spans.windows(2) {
                assert!(w[1].0 >= w[0].1 - 1e-6, "train overlap: {:?}", w);
            }
        }
    }

    #[test]
    fn migration_lets_next_job_start_earlier() {
        let mk_trace = || vec![
            direct_job(0, 200.0, 50.0, 3.0, 8, 0.0),
            direct_job(1, 200.0, 50.0, 3.0, 8, 0.0),
        ];
        let mut with = cfg();
        with.migration.enabled = true;
        let mut without = cfg();
        without.migration.enabled = false;
        let r_with = run_rollmux(with, mk_trace());
        let r_without = run_rollmux(without, mk_trace());
        // If both jobs multiplexed one group, migration pipelines tail and
        // head: makespan must not be worse.
        assert!(
            r_with.makespan_s <= r_without.makespan_s + 1e-6,
            "with: {} without: {}",
            r_with.makespan_s,
            r_without.makespan_s
        );
    }

    #[test]
    fn cost_accounting_consistent() {
        let trace = vec![direct_job(0, 100.0, 50.0, 2.0, 4, 0.0)];
        let res = run_rollmux(cfg(), trace);
        // One group, 8 H20 + 8 H800 for the whole makespan.
        let expect = (8.0 * 1.85 + 8.0 * 5.28) * res.makespan_s / 3600.0;
        assert!((res.cost_usd - expect).abs() < 0.01 * expect, "{} vs {}", res.cost_usd, expect);
        assert!(res.roll_prov_gpu_s > 0.0 && res.train_prov_gpu_s > 0.0);
        assert!(res.roll_busy_gpu_s <= res.roll_prov_gpu_s + 1e-6);
        assert!(res.train_busy_gpu_s <= res.train_prov_gpu_s + 1e-6);
    }

    #[test]
    fn cold_start_ablation_slower() {
        let mk = || vec![
            direct_job(0, 60.0, 40.0, 5.0, 6, 0.0),
            direct_job(1, 60.0, 40.0, 5.0, 6, 0.0),
        ];
        let warm = run_rollmux(cfg(), mk());
        let mut c = cfg();
        c.warm_starts = false;
        let cold = run_rollmux(c, mk());
        assert!(
            cold.makespan_s > warm.makespan_s * 1.15,
            "cold {} vs warm {}",
            cold.makespan_s,
            warm.makespan_s
        );
    }

    #[test]
    fn gantt_off_records_nothing_but_same_outcomes() {
        // The dense engine only materializes PhaseRecords when asked;
        // outcomes must be identical either way (records are pure output).
        let mk = || vec![
            direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
            direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
        ];
        let on = run_rollmux(cfg(), mk());
        let off = run_rollmux(SimConfig::default(), mk());
        assert!(!on.records.is_empty());
        assert!(off.records.is_empty());
        assert_eq!(on.outcomes.len(), off.outcomes.len());
        for (id, o) in &on.outcomes {
            let o2 = &off.outcomes[id];
            assert_eq!(o.finish_s.to_bits(), o2.finish_s.to_bits());
            assert_eq!(o.solo_actual_s.to_bits(), o2.solo_actual_s.to_bits());
            assert_eq!(o.iters, o2.iters);
            assert_eq!(o.migrations, o2.migrations);
        }
        assert_eq!(on.makespan_s.to_bits(), off.makespan_s.to_bits());
        assert_eq!(on.cost_usd.to_bits(), off.cost_usd.to_bits());
        // The streaming busy accumulators never depended on the gantt.
        assert_eq!(on.events_processed, off.events_processed);
        assert_eq!(on.roll_node_busy_gpu_s.len(), off.roll_node_busy_gpu_s.len());
        for (a, b) in on.roll_node_busy_gpu_s.iter().zip(&off.roll_node_busy_gpu_s) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        for (x, y) in on.train_group_busy_gpu_s.iter().zip(&off.train_group_busy_gpu_s) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// The streaming per-node/per-group accumulators must sum to the same
    /// totals as the aggregate busy integrals (within float tolerance:
    /// the aggregate is computed in its original, unchanged expression
    /// order; the per-node mirror decomposes it).
    #[test]
    fn streaming_busy_matches_aggregate_totals() {
        let trace = vec![
            direct_job(0, 200.0, 50.0, 3.0, 8, 0.0),
            direct_job(1, 200.0, 50.0, 3.0, 8, 0.0),
            direct_job(2, 80.0, 60.0, 3.0, 8, 120.0),
        ];
        // Migration on: the tail adjustment path is exercised too.
        let res = run_rollmux(cfg(), trace);
        let roll_sum: f64 = res.roll_node_busy_gpu_s.iter().flatten().sum();
        let train_sum: f64 = res.train_group_busy_gpu_s.iter().sum();
        assert!(
            (roll_sum - res.roll_busy_gpu_s).abs() < 1e-6 * res.roll_busy_gpu_s.max(1.0),
            "per-node {} vs aggregate {}",
            roll_sum,
            res.roll_busy_gpu_s
        );
        assert!(
            (train_sum - res.train_busy_gpu_s).abs() < 1e-6 * res.train_busy_gpu_s.max(1.0),
            "per-group {} vs aggregate {}",
            train_sum,
            res.train_busy_gpu_s
        );
    }

    /// Without migration, the streaming per-node busy must equal the
    /// reconstruction from gantt records — the post-run HashMap+sort
    /// rebuild the accumulators replace.
    #[test]
    fn streaming_busy_matches_record_reconstruction() {
        let trace = vec![
            direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
            direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
            direct_job(2, 60.0, 40.0, 3.0, 6, 100.0),
        ];
        let mut c = cfg();
        c.migration.enabled = false;
        let res = run_rollmux(c, trace);
        let mut by_node: HashMap<(usize, usize), f64> = HashMap::new();
        let mut by_train: HashMap<usize, f64> = HashMap::new();
        for r in &res.records {
            match r.kind {
                PhaseKind::Rollout => {
                    for &n in &r.roll_nodes {
                        *by_node.entry((r.group, n)).or_default() +=
                            (r.end - r.start) * GPUS_PER_NODE as f64;
                    }
                }
                PhaseKind::Train => {
                    *by_train.entry(r.group).or_default() += (r.end - r.start) * 8.0
                }
                _ => {}
            }
        }
        for ((g, n), want) in by_node {
            let got = res.roll_node_busy_gpu_s[g][n];
            assert!((got - want).abs() < 1e-6, "group {g} node {n}: {got} vs {want}");
        }
        for (g, want) in by_train {
            let got = res.train_group_busy_gpu_s[g];
            assert!((got - want).abs() < 1e-6, "group {g} train: {got} vs {want}");
        }
    }

    /// Calendar queue vs binary heap: identical results on a multiplexed
    /// trace (the broad sweep lives in tests/prop_calendar_queue.rs).
    #[test]
    fn calendar_and_heap_engines_agree() {
        let mk = || vec![
            direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
            direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
            direct_job(2, 60.0, 40.0, 3.0, 6, 100.0),
        ];
        let cal = run_rollmux(cfg(), mk());
        let mut c = cfg();
        c.event_queue = EventQueueKind::BinaryHeap;
        let heap = run_rollmux(c, mk());
        assert_eq!(cal.makespan_s.to_bits(), heap.makespan_s.to_bits());
        assert_eq!(cal.cost_usd.to_bits(), heap.cost_usd.to_bits());
        assert_eq!(cal.events_processed, heap.events_processed);
        assert_eq!(cal.outcomes.len(), heap.outcomes.len());
        for (id, a) in &cal.outcomes {
            let b = &heap.outcomes[id];
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
            assert_eq!(a.iters, b.iters);
            assert_eq!(a.migrations, b.migrations);
        }
        assert_eq!(cal.records.len(), heap.records.len());
    }

    /// ISSUE 2 bugfix regression: the migrated tail's busy accounting
    /// must use the `MigrationPlan`'s computed `tail_gpu_frac`, not the
    /// 0.25 the seed engine hard-coded. The trace forces exactly one
    /// migration (job 1 queued behind job 0 on the shared node) and the
    /// expected integral is rebuilt from the engine's own seeded RNG
    /// streams.
    #[test]
    fn tail_busy_accounting_uses_plan_fraction() {
        let t_roll = 100.0;
        let t_train = 80.0;
        let trace = vec![
            direct_job(0, t_roll, t_train, 2.0, 1, 0.0),
            direct_job(1, 80.0, 60.0, 2.0, 1, 0.0),
        ];
        let c = cfg();
        let res = run_rollmux(c.clone(), trace);
        assert_eq!(res.outcomes[&0].migrations, 1, "job 0's tail must consolidate");
        assert_eq!(res.outcomes[&1].migrations, 0, "job 1 has no waiter");

        // Replicate job 0's per-job RNG stream: root = seed ^ id*c (id=0),
        // the JobRt stream is fork(1), one sample_iter draw precedes the
        // rollout, then the two tail forks the engine takes in
        // start_phase.
        let spec = direct_job(0, t_roll, t_train, 2.0, 1, 0.0);
        let mut root = Rng::new(c.seed ^ 0u64.wrapping_mul(0x9E37_79B9));
        let mut jrng = root.fork(1);
        let _ = spec.sample_iter(&c.model, &mut jrng);
        let ts = jrng.fork(0).uniform(0.55, 0.85);
        let tg = jrng.fork(0 ^ 0xabc).uniform(0.1, 0.35);

        let warm = c.switch.warm_s(7.0, crate::cluster::node::PoolKind::Rollout);
        let cold = c.switch.cold_s(7.0, crate::cluster::node::PoolKind::Rollout);
        let base = cold + warm;
        let remaining = (base + t_roll) - (base + ts * t_roll);
        let penalty = c.migration.migrate_cost_s;
        let expect = (warm + t_roll) * 8.0          // job 0's rollout, full pin
            + (warm + 80.0) * 8.0                   // job 1's rollout (no migration)
            - remaining * 8.0                       // the freed node stops counting
            + (remaining + penalty) * tg * 8.0;     // consolidated sub-node tail
        assert!(
            (res.roll_busy_gpu_s - expect).abs() < 1e-6,
            "busy {} vs expected {} (ts {ts}, tg {tg})",
            res.roll_busy_gpu_s,
            expect
        );
        // Guard against the seed bug whenever the sampled fraction is
        // distinguishable from the hard-coded constant.
        if (tg - 0.25).abs() > 1e-3 {
            let buggy = (warm + t_roll) * 8.0 + (warm + 80.0) * 8.0 - remaining * 8.0
                + (remaining + penalty) * 0.25 * 8.0;
            assert!(
                (res.roll_busy_gpu_s - buggy).abs() > 1e-9,
                "accounting still uses the hard-coded 0.25 fraction"
            );
        }
    }

    /// ISSUE 5: a node crash interrupts the resident job, charges a
    /// checkpoint-aware recovery, and the job still completes all its
    /// iterations (goodput strictly below busy).
    #[test]
    fn node_crash_interrupts_and_recovers() {
        let mk = || vec![direct_job(0, 100.0, 50.0, 20.0, 5, 0.0)];
        let mut c = cfg();
        c.faults = Some(FaultConfig {
            seed: 1,
            mtbf_s: 60.0,
            mean_repair_s: 120.0,
            straggler_frac: 0.0,
            straggler_factor: 1.0,
            max_events: 20,
        });
        let res = run_rollmux(c, mk());
        let o = &res.outcomes[&0];
        assert_eq!(o.iters, 5, "all iterations complete despite crashes");
        assert!(res.crashes > 0, "the fault stream must have fired");
        assert!(o.recoveries > 0, "the resident member is always the victim");
        assert!(o.recovery_s > 0.0);
        assert!(res.recovery_time_s > 0.0);
        assert!(res.spills > 0, "a single-node group can only heal by spilling");
        assert!(res.wasted_gpu_s > 0.0, "interrupted progress is discarded work");
        assert!(res.goodput_gpu_s() < res.roll_busy_gpu_s + res.train_busy_gpu_s);
        assert!(res.goodput_frac() < 1.0);
        // Recovery costs wall-clock time vs the fault-free run.
        let nofault = run_rollmux(cfg(), mk());
        assert!(
            res.makespan_s > nofault.makespan_s,
            "chaos {} vs clean {}",
            res.makespan_s,
            nofault.makespan_s
        );
        assert_eq!(nofault.crashes, 0);
        assert_eq!(nofault.wasted_gpu_s, 0.0);
        assert!((nofault.goodput_frac() - 1.0).abs() < 1e-12);
    }

    /// ISSUE 5: a straggler stretches the in-flight rollout without
    /// losing state — no recovery, but wasted (overhead) GPU-time.
    #[test]
    fn straggler_slows_rollout_without_state_loss() {
        let mk = || vec![direct_job(0, 200.0, 50.0, 20.0, 4, 0.0)];
        let mut c = cfg();
        c.faults = Some(FaultConfig {
            seed: 3,
            mtbf_s: 80.0,
            mean_repair_s: 1.0,
            straggler_frac: 1.0, // stragglers only
            straggler_factor: 1.5,
            max_events: 10,
        });
        let res = run_rollmux(c, mk());
        let o = &res.outcomes[&0];
        assert_eq!(o.iters, 4);
        assert_eq!(res.crashes, 0);
        assert_eq!(o.recoveries, 0, "stragglers lose no state");
        assert!(res.stragglers > 0, "some event must hit an in-flight rollout");
        assert!(res.wasted_gpu_s > 0.0, "slowdown overhead is not goodput");
        let nofault = run_rollmux(cfg(), mk());
        assert!(res.makespan_s > nofault.makespan_s);
    }

    #[test]
    fn all_policies_complete_jobs_and_conserve_accounting() {
        for kind in IntraPolicyKind::all() {
            let trace = vec![
                direct_job(0, 100.0, 80.0, 4.0, 6, 0.0),
                direct_job(1, 80.0, 60.0, 4.0, 6, 30.0),
                direct_job(2, 60.0, 40.0, 6.0, 6, 60.0),
            ];
            let mut c = cfg();
            c.intra = kind;
            let res = run_rollmux(c, trace);
            assert_eq!(res.outcomes.len(), 3, "{kind:?}: jobs lost");
            for o in res.outcomes.values() {
                assert_eq!(o.iters, 6, "{kind:?}: iterations lost");
            }
            assert!(res.roll_busy_gpu_s <= res.roll_prov_gpu_s + 1e-6, "{kind:?}");
            assert!(res.train_busy_gpu_s <= res.train_prov_gpu_s + 1e-6, "{kind:?}");
        }
    }

    /// ISSUE 4: rearming a used simulator must be indistinguishable from
    /// constructing a fresh one — every run-visible field resets.
    #[test]
    fn reset_with_trace_matches_fresh_construction() {
        let mk = || vec![
            direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
            direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
            direct_job(2, 60.0, 40.0, 3.0, 6, 100.0),
        ];
        let fresh = run_rollmux(cfg(), mk());
        // Dirty the simulator with an unrelated run first.
        let mut sim = Simulator::new(
            SimConfig::default(),
            InterGroupScheduler::new(PhaseModel::default()),
            vec![direct_job(9, 50.0, 30.0, 4.0, 3, 0.0)],
        );
        let _ = sim.run_to_end();
        let c = cfg();
        sim.reset_with_trace(c.clone(), InterGroupScheduler::new(c.model), mk());
        let reused = sim.run_to_end();
        assert_eq!(fresh.makespan_s.to_bits(), reused.makespan_s.to_bits());
        assert_eq!(fresh.cost_usd.to_bits(), reused.cost_usd.to_bits());
        assert_eq!(fresh.events_processed, reused.events_processed);
        assert_eq!(fresh.records.len(), reused.records.len());
        assert_eq!(fresh.outcomes.len(), reused.outcomes.len());
        for (id, a) in &fresh.outcomes {
            let b = &reused.outcomes[id];
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
            assert_eq!(a.solo_actual_s.to_bits(), b.solo_actual_s.to_bits());
            assert_eq!(a.iters, b.iters);
            assert_eq!(a.migrations, b.migrations);
        }
    }

    fn assert_outcomes_bitwise(a: &SimResult, b: &SimResult) {
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (id, x) in &a.outcomes {
            let y = &b.outcomes[id];
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits(), "job {id}");
            assert_eq!(x.solo_actual_s.to_bits(), y.solo_actual_s.to_bits());
            assert_eq!(x.iters, y.iters);
            assert_eq!(x.migrations, y.migrations);
            assert_eq!(x.recoveries, y.recoveries);
            assert_eq!(x.recovery_s.to_bits(), y.recovery_s.to_bits());
        }
    }

    /// ISSUE 6: the open-world API is the batch engine driven
    /// incrementally — submitting a whole trace up-front and draining
    /// must be bit-identical to `Simulator::new(..).run()`, with and
    /// without the chaos stream.
    #[test]
    fn open_world_submit_matches_batch_run() {
        let mk = || {
            vec![
                direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
                direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
                direct_job(2, 60.0, 40.0, 3.0, 6, 100.0),
            ]
        };
        for faults in [
            None,
            Some(FaultConfig {
                seed: 11,
                mtbf_s: 300.0,
                mean_repair_s: 90.0,
                straggler_frac: 0.3,
                straggler_factor: 1.4,
                max_events: 15,
            }),
        ] {
            let mut c = cfg();
            c.faults = faults;
            let batch = run_rollmux(c.clone(), mk());
            let mut sim = Simulator::open(c.clone(), InterGroupScheduler::new(c.model));
            for j in mk() {
                sim.submit(j);
            }
            let live = sim.run_to_end();
            assert_eq!(batch.makespan_s.to_bits(), live.makespan_s.to_bits());
            assert_eq!(batch.cost_usd.to_bits(), live.cost_usd.to_bits());
            assert_eq!(batch.events_processed, live.events_processed);
            assert_eq!(batch.crashes, live.crashes);
            assert_eq!(batch.stragglers, live.stragglers);
            assert_eq!(batch.wasted_gpu_s.to_bits(), live.wasted_gpu_s.to_bits());
            assert_eq!(live.cancelled, 0);
            assert_outcomes_bitwise(&batch, &live);
        }
    }

    /// ISSUE 6: stepping time in fixed increments changes only where
    /// the clock stops (makespan = last deadline) — every job outcome,
    /// busy integral and dollar is bit-identical to the batch run.
    #[test]
    fn step_until_increments_preserve_outcomes() {
        let mk = || {
            vec![
                direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
                direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
            ]
        };
        let batch = run_rollmux(cfg(), mk());
        let c = cfg();
        let mut sim = Simulator::open(c.clone(), InterGroupScheduler::new(c.model));
        for j in mk() {
            sim.submit(j);
        }
        let mut t = 0.0;
        while sim.outstanding() > 0 {
            t += 500.0;
            sim.step_until(t);
        }
        let live = sim.run_to_end();
        assert_outcomes_bitwise(&batch, &live);
        assert_eq!(batch.cost_usd.to_bits(), live.cost_usd.to_bits());
        assert_eq!(batch.roll_busy_gpu_s.to_bits(), live.roll_busy_gpu_s.to_bits());
        assert_eq!(batch.train_busy_gpu_s.to_bits(), live.train_busy_gpu_s.to_bits());
        assert_eq!(batch.events_processed, live.events_processed);
        // The stepped clock stops at the last idle deadline, at or
        // after the batch makespan.
        assert!(live.makespan_s >= batch.makespan_s);
    }

    /// ISSUE 6: cancelling a live job frees its capacity immediately,
    /// counts as cancelled (not an outcome), and a trial-admission
    /// rollback restores the peak accounting to the pre-trial snapshot.
    #[test]
    fn cancel_and_rollback_admission() {
        let c = SimConfig::default();
        let mut sim = Simulator::open(c.clone(), InterGroupScheduler::new(c.model));
        sim.submit(direct_job(0, 100.0, 50.0, 2.0, 50, 0.0));
        sim.step_until(0.0);
        assert!(sim.job_placement(0).is_some());
        let (r0, t0) = sim.sched.gpus_in_use();
        assert!(r0 + t0 > 0);

        // Trial-admit a second job that lands on fresh capacity, then
        // roll it back: provisioned GPUs and peaks return to baseline.
        let mark = sim.usage_mark();
        sim.submit(direct_job(1, 500.0, 400.0, 1.05, 50, 0.0));
        sim.step_until(sim.now());
        let (r1, t1) = sim.sched.gpus_in_use();
        assert!(r1 + t1 > r0 + t0, "trial must provision more capacity");
        assert!(sim.rollback_admission(1, mark));
        let (r2, t2) = sim.sched.gpus_in_use();
        assert_eq!((r2, t2), (r0, t0));
        assert!(!sim.rollback_admission(1, mark), "rollback is idempotent");

        // Cancel the remaining job mid-run and drain: no outcomes, two
        // cancelled, peaks equal the single-job baseline.
        sim.step_until(400.0);
        assert!(sim.cancel_job(0));
        assert!(!sim.cancel_job(0), "cancel is idempotent");
        assert_eq!(sim.outstanding(), 0);
        let res = sim.run_to_end();
        assert_eq!(res.outcomes.len(), 0);
        assert_eq!(res.cancelled, 2);
        assert_eq!((res.peak_roll_gpus, res.peak_train_gpus), (r0, t0));
        assert!(res.cost_usd > 0.0, "the cancelled job's runtime still cost money");
    }

    /// ISSUE 6: named fault injection validates its target and routes
    /// through the same repair surgery as stream faults.
    #[test]
    fn inject_named_faults_validates_targets() {
        let c = SimConfig::default();
        let mut sim = Simulator::open(c.clone(), InterGroupScheduler::new(c.model));
        sim.submit(direct_job(0, 100.0, 50.0, 20.0, 4, 0.0));
        sim.step_until(0.0);
        let (gid, _) = sim.job_placement(0).expect("placed");
        assert!(!sim.inject_node_crash(gid + 7, 0, 60.0), "unknown group");
        assert!(!sim.inject_node_crash(gid, 99, 60.0), "node out of range");
        assert!(!sim.inject_straggler(gid, 0, 0.5), "not a slowdown");
        // A real crash mid-rollout: the member recovers and completes.
        sim.step_until(50.0);
        assert!(sim.inject_node_crash(gid, 0, 60.0));
        let res = sim.run_to_end();
        assert_eq!(res.crashes, 1);
        assert_eq!(res.outcomes[&0].iters, 4);
        assert!(res.outcomes[&0].recoveries > 0);
        assert!(res.wasted_gpu_s > 0.0);
    }

    #[test]
    fn default_policy_is_work_conserving_fifo() {
        assert_eq!(SimConfig::default().intra, IntraPolicyKind::WorkConservingFifo);
        let mk = || vec![
            direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
            direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
        ];
        let a = run_rollmux(SimConfig::default(), mk());
        let mut c = SimConfig::default();
        c.intra = IntraPolicyKind::WorkConservingFifo;
        let b = run_rollmux(c, mk());
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.cost_usd.to_bits(), b.cost_usd.to_bits());
    }

    fn assert_results_bitwise(a: &SimResult, b: &SimResult, tag: &str) {
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{tag}: makespan");
        assert_eq!(a.cost_usd.to_bits(), b.cost_usd.to_bits(), "{tag}: cost");
        assert_eq!(a.roll_busy_gpu_s.to_bits(), b.roll_busy_gpu_s.to_bits(), "{tag}: roll busy");
        assert_eq!(a.train_busy_gpu_s.to_bits(), b.train_busy_gpu_s.to_bits(), "{tag}: train busy");
        assert_eq!(a.roll_prov_gpu_s.to_bits(), b.roll_prov_gpu_s.to_bits(), "{tag}: roll prov");
        assert_eq!(a.train_prov_gpu_s.to_bits(), b.train_prov_gpu_s.to_bits(), "{tag}: train prov");
        assert_eq!(a.wasted_gpu_s.to_bits(), b.wasted_gpu_s.to_bits(), "{tag}: wasted");
        assert_eq!(a.recovery_time_s.to_bits(), b.recovery_time_s.to_bits(), "{tag}: recovery");
        assert_eq!(a.events_processed, b.events_processed, "{tag}: event count");
        assert_eq!(a.crashes, b.crashes, "{tag}: crashes");
        assert_eq!(a.stragglers, b.stragglers, "{tag}: stragglers");
        assert_eq!(a.evictions, b.evictions, "{tag}: evictions");
        assert_eq!(a.spills, b.spills, "{tag}: spills");
        assert_eq!(a.cancelled, b.cancelled, "{tag}: cancelled");
        assert_eq!(
            (a.peak_roll_gpus, a.peak_train_gpus),
            (b.peak_roll_gpus, b.peak_train_gpus),
            "{tag}: peaks"
        );
        assert_eq!(a.roll_node_busy_gpu_s.len(), b.roll_node_busy_gpu_s.len(), "{tag}: node dims");
        for (g, (x, y)) in a.roll_node_busy_gpu_s.iter().zip(&b.roll_node_busy_gpu_s).enumerate() {
            assert_eq!(x.len(), y.len(), "{tag}: group {g} node dims");
            for (n, (p, q)) in x.iter().zip(y).enumerate() {
                assert_eq!(p.to_bits(), q.to_bits(), "{tag}: group {g} node {n} busy");
            }
        }
        assert_eq!(a.train_group_busy_gpu_s.len(), b.train_group_busy_gpu_s.len(), "{tag}: train dims");
        for (g, (p, q)) in a.train_group_busy_gpu_s.iter().zip(&b.train_group_busy_gpu_s).enumerate() {
            assert_eq!(p.to_bits(), q.to_bits(), "{tag}: group {g} train busy");
        }
        // ISSUE 9: the recorded streams themselves are part of the
        // bitwise contract — canonical sorting at finalize makes them
        // identical across serial/parallel/forked execution.
        assert_eq!(a.records.len(), b.records.len(), "{tag}: record count");
        for (i, (x, y)) in a.records.iter().zip(&b.records).enumerate() {
            assert_eq!(x.start.to_bits(), y.start.to_bits(), "{tag}: record {i} start");
            assert_eq!(x.end.to_bits(), y.end.to_bits(), "{tag}: record {i} end");
            assert_eq!(x, y, "{tag}: record {i}");
        }
        assert_eq!(a.flight.len(), b.flight.len(), "{tag}: flight frame count");
        assert_eq!(a.flight, b.flight, "{tag}: flight stream");
        assert_outcomes_bitwise(a, b);
    }

    /// ISSUE 7: the group-parallel window drain is bit-identical to the
    /// serial loop — outcomes, busy integrals (aggregate, per node, per
    /// group pool), dollars, event counts and chaos accounting — across
    /// worker counts, all intra policies, with and without the fault
    /// stream. `workers = 1` is the serial loop itself; `workers = 4`
    /// exercises lane take/merge, window barriers, and completion
    /// deferral on a heterogeneous fleet trace.
    #[test]
    fn run_parallel_matches_serial_bitwise() {
        let mk = || crate::workload::trace::fleet_trace(17, 120, 1.0);
        for faults in [
            None,
            Some(FaultConfig {
                seed: 5,
                mtbf_s: 4.0 * 3600.0,
                mean_repair_s: 600.0,
                straggler_frac: 0.3,
                straggler_factor: 1.4,
                max_events: 40,
            }),
        ] {
            for kind in IntraPolicyKind::all() {
                let mut c = SimConfig::default();
                c.intra = kind;
                c.faults = faults.clone();
                // Both recorded streams on: the canonical sort at finalize
                // must make them bit-identical across paths too (ISSUE 9).
                c.record_gantt = true;
                c.record_flight = true;
                let serial = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk())
                    .run_to_end();
                if faults.is_some() {
                    assert!(serial.crashes + serial.stragglers > 0, "chaos must fire");
                }
                for workers in [1usize, 4] {
                    let tag = format!(
                        "{kind:?} workers={workers} faults={}",
                        faults.is_some()
                    );
                    let mut sim =
                        Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk());
                    let par = sim.run_parallel(workers);
                    assert_results_bitwise(&serial, &par, &tag);
                }
            }
        }
    }

    /// ISSUE 7: tiny windows below `PAR_WINDOW_MIN_EVENTS` drain inline
    /// on the coordinator through the same lane code — a two-job trace
    /// (every window tiny) still matches exactly with workers > 1.
    #[test]
    fn run_parallel_inline_small_windows() {
        let mk = || {
            vec![
                direct_job(0, 100.0, 80.0, 2.0, 6, 0.0),
                direct_job(1, 80.0, 60.0, 2.0, 6, 50.0),
            ]
        };
        let c = SimConfig::default();
        let serial = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk()).run_to_end();
        let mut sim = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk());
        let par = sim.run_parallel(8);
        assert_results_bitwise(&serial, &par, "small-window inline");
    }

    /// ISSUE 9: `run_until` pops without advancing the clock past the
    /// last real event, so draining everything through it and then
    /// finalizing yields the exact uninterrupted makespan.
    #[test]
    fn run_until_pops_without_advancing_clock() {
        let trace = vec![direct_job(0, 100.0, 50.0, 2.0, 5, 0.0)];
        let c = cfg();
        let mut sim = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), trace.clone());
        sim.run_until(1e12);
        let res = sim.run_to_end();
        let oracle = run_rollmux(c, trace);
        assert_eq!(res.makespan_s.to_bits(), oracle.makespan_s.to_bits());
        assert_eq!(res.events_processed, oracle.events_processed);
    }

    /// ISSUE 9: arming the flight recorder changes nothing but the
    /// stream itself, and the stream's phase view IS the gantt stream.
    #[test]
    fn recorder_arming_does_not_change_results() {
        let mk = || crate::workload::trace::fleet_trace(23, 60, 1.0);
        let mut c = SimConfig::default();
        c.record_gantt = true;
        let off = run_rollmux(c.clone(), mk());
        c.record_flight = true;
        let mut on = run_rollmux(c, mk());
        assert!(off.flight.is_empty(), "disarmed recorder must stay empty");
        assert!(!on.flight.is_empty(), "armed recorder must capture frames");
        let from_flight: Vec<PhaseRecord> = on.flight.phase_records().cloned().collect();
        assert_eq!(from_flight, on.records, "flight phase view == gantt stream");
        on.flight = FlightRecorder::default();
        assert_results_bitwise(&off, &on, "recorder off vs on");
    }

    /// ISSUE 9: a snapshot taken mid-run is non-destructive AND restores
    /// into a simulator whose drained result is bit-identical to the
    /// uninterrupted run — chaos on/off, both recorders armed, all intra
    /// policies.
    #[test]
    fn snapshot_restore_mid_run_bitwise() {
        let mk = || crate::workload::trace::fleet_trace(17, 80, 1.0);
        for faults in [
            None,
            Some(FaultConfig {
                seed: 5,
                mtbf_s: 2.0 * 3600.0,
                mean_repair_s: 600.0,
                straggler_frac: 0.3,
                straggler_factor: 1.4,
                max_events: 30,
            }),
        ] {
            for kind in IntraPolicyKind::all() {
                let mut c = SimConfig::default();
                c.record_gantt = true;
                c.record_flight = true;
                c.intra = kind;
                c.faults = faults.clone();
                let oracle =
                    Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk()).run_to_end();
                let t = oracle.makespan_s * 0.4;
                let tag = format!("{kind:?} faults={}", faults.is_some());
                let mut pre = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk());
                let snap = pre.fork_at(t);
                assert!(snap.t() <= t, "{tag}: clock must not pass the fork point");
                assert_results_bitwise(&oracle, &pre.run_to_end(), &format!("{tag} prefix"));
                let trace = mk();
                let restored = Simulator::restore(c.clone(), &trace, &snap).run_to_end();
                assert_results_bitwise(&oracle, &restored, &format!("{tag} restored"));
            }
        }
    }

    /// ISSUE 9: fork-at-t branches are bit-identical to from-scratch runs
    /// applying the same divergence at the same time — a policy swap, a
    /// group-cap reconfig, and a late submission burst.
    #[test]
    fn fork_branches_match_from_scratch() {
        let mk = || crate::workload::trace::fleet_trace(29, 80, 1.0);
        let mut c = SimConfig::default();
        c.record_gantt = true;
        c.record_flight = true;
        let base = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk()).run_to_end();
        let t = base.makespan_s * 0.3;
        let mut pre = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk());
        let snap = pre.fork_at(t);
        let trace = mk();
        let diverge = |sim: &mut Simulator<InterGroupScheduler>, branch: usize| match branch {
            0 => sim.set_intra_policy(IntraPolicyKind::StrictRoundRobin),
            1 => sim.set_intra_policy(IntraPolicyKind::SloSlackPriority),
            2 => {
                sim.reconfig_group_cap(Some(2));
            }
            _ => {
                sim.submit(direct_job(900, 90.0, 70.0, 3.0, 4, t));
                sim.submit(direct_job(901, 60.0, 40.0, 3.0, 4, t));
            }
        };
        for branch in 0..4 {
            let mut fork = Simulator::restore(c.clone(), &trace, &snap);
            diverge(&mut fork, branch);
            let forked = fork.run_to_end();
            let mut scratch = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk());
            scratch.run_until(t);
            diverge(&mut scratch, branch);
            let oracle = scratch.run_to_end();
            assert_results_bitwise(&oracle, &forked, &format!("fork branch {branch}"));
        }
    }

    /// ISSUE 9: the byte codec roundtrips exactly (same state → same
    /// bytes → same state), a decoded image restores bit-identically,
    /// and corrupt images error instead of panicking.
    #[test]
    fn snapshot_codec_roundtrip_and_errors() {
        let mk = || crate::workload::trace::fleet_trace(31, 40, 1.0);
        let mut c = SimConfig::default();
        c.record_flight = true;
        c.faults = Some(FaultConfig {
            seed: 7,
            mtbf_s: 3600.0,
            mean_repair_s: 300.0,
            straggler_frac: 0.5,
            straggler_factor: 1.5,
            max_events: 20,
        });
        let oracle =
            Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk()).run_to_end();
        let mut pre = Simulator::new(c.clone(), InterGroupScheduler::new(c.model), mk());
        let snap = pre.fork_at(oracle.makespan_s * 0.5);
        let bytes = snap.to_bytes();
        let decoded = SimSnapshot::from_bytes(&bytes).expect("roundtrip decodes");
        assert_eq!(bytes, decoded.to_bytes(), "byte image is a fixed point");
        assert_eq!(snap.live_jobs(), decoded.live_jobs());
        assert_eq!(snap.pending_events(), decoded.pending_events());
        let trace = mk();
        let a = Simulator::restore(c.clone(), &trace, &snap).run_to_end();
        let b = Simulator::restore(c.clone(), &trace, &decoded).run_to_end();
        assert_results_bitwise(&a, &b, "decoded snapshot");
        assert_results_bitwise(&oracle, &a, "restored vs oracle");
        assert!(SimSnapshot::from_bytes(&bytes[..bytes.len() - 3]).is_err(), "truncation");
        assert!(SimSnapshot::from_bytes(b"NOTSNAP0 junk").is_err(), "bad magic");
        let mut trailing = bytes.clone();
        trailing.extend_from_slice(&[0u8; 8]);
        assert!(SimSnapshot::from_bytes(&trailing).is_err(), "trailing bytes");
    }
}
