//! Bench: PJRT runtime phase execution on the tiny artifacts — per-phase
//! latency and the L3 dispatch overhead (literal prep + untuple) vs pure
//! compute (paper-relevant: the request path must be scheduler-bound,
//! not runtime-overhead-bound).

use rollmux::runtime::ModelRuntime;
use rollmux::util::bench;

fn main() {
    println!("== runtime_exec ==");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        println!("skipped: run `make artifacts` first");
        return;
    }
    let t0 = std::time::Instant::now();
    let rt = ModelRuntime::load(dir).expect("load");
    println!("load+compile all artifacts: {:.2}s", t0.elapsed().as_secs_f64());

    let mut state = rt.init(0).expect("init");
    let (b, t, p) = (rt.batch(), rt.seq_len(), rt.prompt_len());
    let mut prompt = vec![0i32; b * t];
    for bi in 0..b {
        for ti in 0..p {
            prompt[bi * t + ti] = ((bi + ti) % rt.vocab()) as i32;
        }
    }
    let stats = bench(2, 20, || rt.rollout(&state.params, &prompt, 1, 1.0).unwrap());
    stats.report(&format!("rollout_phase ({} new tokens)", t - p));
    let per_tok = stats.mean_s / (t - p) as f64;
    println!("  -> {:.2} ms/token fused", per_tok * 1e3);

    let stats1 = bench(2, 10, || {
        rt.rollout_one_step(&state.params, &prompt, p as i32, 1, 1.0).unwrap()
    });
    stats1.report("rollout_one_step (hook-driven path)");
    println!(
        "  -> per-step dispatch overhead vs fused: {:.2}x",
        stats1.mean_s / per_tok
    );

    let tokens = rt.rollout(&state.params, &prompt, 1, 1.0).unwrap().tokens;
    let mask = vec![1.0f32; b * t];
    let adv = vec![0.5f32; b];
    let stats = bench(2, 20, || {
        rt.train(&mut state, &tokens, &mask, &adv, 1e-3, 0.01).unwrap()
    });
    stats.report("train_step (fused PG + Adam)");

    let stats = bench(2, 20, || rt.logits(&state.params, &prompt).unwrap());
    stats.report("forward (logits only)");
}
