//! Bench: `rollmuxd` control-plane costs (ISSUE 6) — admission
//! throughput through the bounded queue + trial-admission path, the
//! write-ahead journal's append overhead, and cold-start journal
//! replay (crash recovery). Set BENCH_JSON_OUT (scripts/bench.sh does)
//! to collect machine-readable records for BENCH_6.json.

use std::fs;
use std::path::PathBuf;

use rollmux::runtime::{Daemon, DaemonConfig};
use rollmux::util::bench;

const BIN: &str = "daemon";

fn admit_line(id: usize) -> String {
    let t_roll = 100.0 + (id % 7) as f64 * 10.0;
    format!(
        "{{\"cmd\":\"admit\",\"job\":{{\"id\":{id},\"n_iters\":6,\"slo\":3.0,\
         \"n_roll_gpus\":8,\"n_train_gpus\":8,\"params_b\":7.0,\
         \"t_roll\":{t_roll},\"t_train\":70}}}}"
    )
}

/// One operator session: n admits interleaved with time advances.
fn session(n: usize) -> Vec<String> {
    let mut s = Vec::new();
    for id in 0..n {
        s.push(admit_line(id));
        if id % 8 == 7 {
            s.push("{\"cmd\":\"advance\",\"dt\":50}".into());
        }
    }
    s
}

fn scratch(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("rollmux_bench_daemon_{}_{tag}.jsonl", std::process::id()));
    p
}

fn main() {
    println!("== daemon ==");

    // Admission throughput on the virtual cluster, journal disabled:
    // parse + validate + trial-admit (usage mark / submit / cap check)
    // per command line.
    for &n in &[64usize, 256] {
        let lines = session(n);
        let stats = bench(2, 10, || {
            let mut d = Daemon::new_virtual(DaemonConfig::default());
            let mut replies = 0usize;
            for l in &lines {
                replies += d.handle_line(l).len();
            }
            assert!(replies >= n);
            replies
        });
        stats.report_json(BIN, &format!("admit_throughput @{n} jobs"), lines.len() as f64);
    }

    // Same session with the write-ahead journal armed: measures the
    // append + fsync-batching overhead on the admission path.
    {
        let n = 256usize;
        let lines = session(n);
        let path = scratch("wal");
        let stats = bench(2, 10, || {
            let _ = fs::remove_file(&path);
            let mut d = Daemon::new_virtual(DaemonConfig::default());
            d.attach_journal(&path).expect("attach journal");
            for l in &lines {
                d.handle_line(l);
            }
            d.flush().expect("flush journal");
        });
        let _ = fs::remove_file(&path);
        stats.report_json(BIN, &format!("admit_journaled @{n} jobs"), lines.len() as f64);
    }

    // Cold-start crash recovery: replay a journaled session into a
    // fresh daemon (scan + CRC checks + command re-application).
    for &n in &[256usize, 1024] {
        let lines = session(n);
        let path = scratch(&format!("replay_{n}"));
        let _ = fs::remove_file(&path);
        let mut d = Daemon::new_virtual(DaemonConfig::default());
        d.attach_journal(&path).expect("attach journal");
        for l in &lines {
            d.handle_line(l);
        }
        d.flush().expect("flush journal");
        drop(d);
        let stats = bench(2, 10, || {
            let mut d = Daemon::new_virtual(DaemonConfig::default());
            let replayed = d.attach_journal(&path).expect("replay journal");
            assert_eq!(replayed, lines.len());
            replayed
        });
        let _ = fs::remove_file(&path);
        stats.report_json(BIN, &format!("journal_replay @{n} cmds"), lines.len() as f64);
    }
}
