//! Bench: `rollmuxd` control-plane costs (ISSUES 6, 8) — admission
//! throughput through the bounded queue + trial-admission path, the
//! write-ahead journal's append overhead, cold-start journal replay
//! (crash recovery), live reconfiguration, and multi-tenant admission
//! through the socket-arbiter entry point. Set BENCH_JSON_OUT
//! (scripts/bench.sh does) to collect machine-readable records for
//! BENCH_<gen>.json.

use std::fs;
use std::path::PathBuf;

use rollmux::runtime::{Daemon, DaemonConfig};
use rollmux::util::bench;

const BIN: &str = "daemon";

fn admit_line(id: usize) -> String {
    let t_roll = 100.0 + (id % 7) as f64 * 10.0;
    format!(
        "{{\"cmd\":\"admit\",\"job\":{{\"id\":{id},\"n_iters\":6,\"slo\":3.0,\
         \"n_roll_gpus\":8,\"n_train_gpus\":8,\"params_b\":7.0,\
         \"t_roll\":{t_roll},\"t_train\":70}}}}"
    )
}

/// One operator session: n admits interleaved with time advances.
fn session(n: usize) -> Vec<String> {
    let mut s = Vec::new();
    for id in 0..n {
        s.push(admit_line(id));
        if id % 8 == 7 {
            s.push("{\"cmd\":\"advance\",\"dt\":50}".into());
        }
    }
    s
}

fn scratch(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("rollmux_bench_daemon_{}_{tag}.jsonl", std::process::id()));
    p
}

fn main() {
    println!("== daemon ==");

    // Admission throughput on the virtual cluster, journal disabled:
    // parse + validate + trial-admit (usage mark / submit / cap check)
    // per command line.
    for &n in &[64usize, 256] {
        let lines = session(n);
        let stats = bench(2, 10, || {
            let mut d = Daemon::new_virtual(DaemonConfig::default());
            let mut replies = 0usize;
            for l in &lines {
                replies += d.handle_line(l).len();
            }
            assert!(replies >= n);
            replies
        });
        stats.report_json(BIN, &format!("admit_throughput @{n} jobs"), lines.len() as f64);
    }

    // Same session with the write-ahead journal armed: measures the
    // append + fsync-batching overhead on the admission path.
    {
        let n = 256usize;
        let lines = session(n);
        let path = scratch("wal");
        let stats = bench(2, 10, || {
            let _ = fs::remove_file(&path);
            let mut d = Daemon::new_virtual(DaemonConfig::default());
            d.attach_journal(&path).expect("attach journal");
            for l in &lines {
                d.handle_line(l);
            }
            d.flush().expect("flush journal");
        });
        let _ = fs::remove_file(&path);
        stats.report_json(BIN, &format!("admit_journaled @{n} jobs"), lines.len() as f64);
    }

    // Cold-start crash recovery: replay a journaled session into a
    // fresh daemon (scan + CRC checks + command re-application).
    for &n in &[256usize, 1024] {
        let lines = session(n);
        let path = scratch(&format!("replay_{n}"));
        let _ = fs::remove_file(&path);
        let mut d = Daemon::new_virtual(DaemonConfig::default());
        d.attach_journal(&path).expect("attach journal");
        for l in &lines {
            d.handle_line(l);
        }
        d.flush().expect("flush journal");
        drop(d);
        let stats = bench(2, 10, || {
            let mut d = Daemon::new_virtual(DaemonConfig::default());
            let replayed = d.attach_journal(&path).expect("replay journal");
            assert_eq!(replayed, lines.len());
            replayed
        });
        let _ = fs::remove_file(&path);
        stats.report_json(BIN, &format!("journal_replay @{n} cmds"), lines.len() as f64);
    }

    // Live reconfiguration (ISSUE 8): a loaded daemon absorbing
    // alternating gpu_cap / intra-policy / queue_cap reconfigs. Counts
    // the validate + apply + re-pump + event-staging path per command.
    {
        let n_jobs = 64usize;
        let n_reconfigs = 32usize;
        let setup = session(n_jobs);
        let reconfigs: Vec<String> = (0..n_reconfigs)
            .map(|i| match i % 3 {
                0 => format!("{{\"cmd\":\"reconfig\",\"gpu_cap\":{}}}", 512 + 64 * (i % 4)),
                1 => {
                    let p = if i % 2 == 1 { "round-robin" } else { "fifo" };
                    format!("{{\"cmd\":\"reconfig\",\"intra\":\"{p}\"}}")
                }
                _ => format!("{{\"cmd\":\"reconfig\",\"queue_cap\":{}}}", 16 + (i % 5)),
            })
            .collect();
        let stats = bench(2, 10, || {
            let mut d = Daemon::new_virtual(DaemonConfig::default());
            for l in &setup {
                d.handle_line(l);
            }
            for l in &reconfigs {
                let out = d.handle_line(l);
                assert!(out.iter().any(|r| r.contains("\"ok\":\"reconfig\"")));
            }
            d.stats().reconfigs
        });
        stats.report_json(
            BIN,
            &format!("reconfig_apply @{n_reconfigs} on {n_jobs} jobs"),
            reconfigs.len() as f64,
        );
    }

    // Multi-tenant admission through the arbiter entry point
    // (handle_from): same workload as admit_throughput but fanned over
    // 8 tenants with an event subscriber attached — measures the
    // routing + tenant-fairness + fanout overhead on the hot path.
    {
        let n = 256usize;
        let lines = session(n);
        let stats = bench(2, 10, || {
            let mut d = Daemon::new_virtual(DaemonConfig { tenant_cap: 64, ..Default::default() });
            let sub = d.handle_from(9, "{\"cmd\":\"subscribe\"}");
            assert_eq!(sub.len(), 1);
            let mut routed = 0usize;
            for (i, l) in lines.iter().enumerate() {
                let tenant = 1 + (i % 8) as u32;
                routed += d.handle_from(tenant, l).len();
            }
            assert!(routed >= n);
            routed
        });
        stats.report_json(BIN, &format!("socket_admit_throughput @{n} jobs x8 tenants"), lines.len() as f64);
    }
}
