//! Bench: discrete-event engine throughput (phases simulated per second)
//! on the at-scale traces — the hot path of every Fig. 13/14/15 sweep.

use rollmux::cluster::PhaseModel;
use rollmux::coordinator::inter::InterGroupScheduler;
use rollmux::sim::engine::{SimConfig, Simulator};
use rollmux::util::{bench, timed};
use rollmux::workload::trace::{philly_trace, production_trace, SloPolicy};
use rollmux::workload::profiles::SimProfile;

fn main() {
    println!("== simulator ==");
    // Production trace replay (Fig. 13 inner loop).
    for &n_jobs in &[50usize, 120, 200] {
        let trace = production_trace(7, n_jobs);
        let stats = bench(1, 5, || {
            let cfg = SimConfig { seed: 7, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone()).run()
        });
        stats.report(&format!("replay/production @{n_jobs} jobs"));
    }
    // Philly trace (Fig. 14/15 inner loop) with phase-count reporting.
    let trace = philly_trace(7, 300, SimProfile::Mixed, SloPolicy::Drawn(1.0, 2.0));
    let (res, secs) = timed(|| {
        let cfg = SimConfig { seed: 7, ..Default::default() };
        Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone()).run()
    });
    let iters: usize = res.outcomes.values().map(|o| o.iters).sum();
    println!(
        "replay/philly @300 jobs: {:.2}s wall, {} iterations, {:.0} phases/s",
        secs,
        iters,
        (iters * 4) as f64 / secs
    );
}
