//! Bench: discrete-event engine throughput (phases simulated per second)
//! on the at-scale traces — the hot path of every Fig. 13/14/15 sweep.
//! Set BENCH_JSON_OUT (scripts/bench.sh does) to collect machine-readable
//! records for BENCH_1.json.

use rollmux::cluster::PhaseModel;
use rollmux::coordinator::inter::InterGroupScheduler;
use rollmux::sim::engine::{EventQueueKind, SimConfig, Simulator};
use rollmux::util::{bench, emit_bench_json, timed};
use rollmux::workload::trace::{philly_trace, production_trace, SloPolicy};
use rollmux::workload::profiles::SimProfile;

const BIN: &str = "simulator";

fn main() {
    println!("== simulator ==");
    // Production trace replay (Fig. 13 inner loop).
    for &n_jobs in &[50usize, 120, 200] {
        let trace = production_trace(7, n_jobs);
        // Iterations are trace-determined; count them once for phases/s
        // (each iteration = rollout + train + sync, plus one init/job).
        let probe = {
            let cfg = SimConfig { seed: 7, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone()).run()
        };
        let iters: usize = probe.outcomes.values().map(|o| o.iters).sum();
        let phases = iters * 3 + n_jobs;
        let stats = bench(1, 5, || {
            let cfg = SimConfig { seed: 7, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone()).run()
        });
        stats.report_json(BIN, &format!("replay/production @{n_jobs} jobs"), phases as f64);
    }
    // Philly trace (Fig. 14/15 inner loop) with phase-count reporting.
    // Same phase definition as the production records above: rollout +
    // train + sync per iteration, one init per job.
    let trace = philly_trace(7, 300, SimProfile::Mixed, SloPolicy::Drawn(1.0, 2.0));
    let (res, secs) = timed(|| {
        let cfg = SimConfig { seed: 7, ..Default::default() };
        Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone()).run()
    });
    let iters: usize = res.outcomes.values().map(|o| o.iters).sum();
    let phases_per_s = (iters * 3 + trace.len()) as f64 / secs;
    println!(
        "replay/philly @300 jobs: {:.2}s wall, {} iterations, {:.0} phases/s",
        secs, iters, phases_per_s
    );
    emit_bench_json(
        BIN,
        "replay/philly @300 jobs",
        &[
            ("wall_s", secs),
            ("iterations", iters as f64),
            ("phases_per_s", phases_per_s),
        ],
    );

    // ISSUE 3: raw event-engine throughput (events/s), calendar queue vs
    // the historical binary heap on the same trace. Results are
    // property-tested bit-identical; only the queue changes.
    for (name, kind) in [
        ("engine/events_calendar @200 jobs", EventQueueKind::Calendar),
        ("engine/events_heap @200 jobs", EventQueueKind::BinaryHeap),
    ] {
        let trace = production_trace(7, 200);
        let events = {
            let cfg = SimConfig { seed: 7, event_queue: kind, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone())
                .run()
                .events_processed
        };
        let stats = bench(1, 5, || {
            let cfg = SimConfig { seed: 7, event_queue: kind, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone())
                .run()
        });
        stats.report(name);
        emit_bench_json(
            BIN,
            name,
            &[
                ("mean_s", stats.mean_s),
                ("events", events as f64),
                ("events_per_s", events as f64 / stats.mean_s.max(1e-12)),
            ],
        );
    }
}
