//! Bench: discrete-event engine throughput (phases simulated per second)
//! on the at-scale traces — the hot path of every Fig. 13/14/15 sweep.
//! Set BENCH_JSON_OUT (scripts/bench.sh does) to collect machine-readable
//! records for BENCH_1.json.

use rollmux::cluster::PhaseModel;
use rollmux::coordinator::inter::InterGroupScheduler;
use rollmux::sim::engine::{run_sim, EventQueueKind, Fidelity, SimConfig, Simulator};
use rollmux::sim::faults::FaultConfig;
use rollmux::util::{bench, emit_bench_json, timed};
use rollmux::workload::trace::{fleet_trace, philly_trace, production_trace, SloPolicy};
use rollmux::workload::profiles::SimProfile;

const BIN: &str = "simulator";

fn main() {
    println!("== simulator ==");
    // Production trace replay (Fig. 13 inner loop).
    for &n_jobs in &[50usize, 120, 200] {
        let trace = production_trace(7, n_jobs);
        // Iterations are trace-determined; count them once for phases/s
        // (each iteration = rollout + train + sync, plus one init/job).
        let probe = {
            let cfg = SimConfig { seed: 7, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone()).run()
        };
        let iters: usize = probe.outcomes.values().map(|o| o.iters).sum();
        let phases = iters * 3 + n_jobs;
        let stats = bench(1, 5, || {
            let cfg = SimConfig { seed: 7, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone()).run()
        });
        stats.report_json(BIN, &format!("replay/production @{n_jobs} jobs"), phases as f64);
    }
    // Philly trace (Fig. 14/15 inner loop) with phase-count reporting.
    // Same phase definition as the production records above: rollout +
    // train + sync per iteration, one init per job.
    let trace = philly_trace(7, 300, SimProfile::Mixed, SloPolicy::Drawn(1.0, 2.0));
    let (res, secs) = timed(|| {
        let cfg = SimConfig { seed: 7, ..Default::default() };
        Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone()).run()
    });
    let iters: usize = res.outcomes.values().map(|o| o.iters).sum();
    let phases_per_s = (iters * 3 + trace.len()) as f64 / secs;
    println!(
        "replay/philly @300 jobs: {:.2}s wall, {} iterations, {:.0} phases/s",
        secs, iters, phases_per_s
    );
    emit_bench_json(
        BIN,
        "replay/philly @300 jobs",
        &[
            ("wall_s", secs),
            ("iterations", iters as f64),
            ("phases_per_s", phases_per_s),
        ],
    );

    // ISSUE 3: raw event-engine throughput (events/s), calendar queue vs
    // the historical binary heap on the same trace. Results are
    // property-tested bit-identical; only the queue changes.
    for (name, kind) in [
        ("engine/events_calendar @200 jobs", EventQueueKind::Calendar),
        ("engine/events_heap @200 jobs", EventQueueKind::BinaryHeap),
    ] {
        let trace = production_trace(7, 200);
        let events = {
            let cfg = SimConfig { seed: 7, event_queue: kind, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone())
                .run()
                .events_processed
        };
        let stats = bench(1, 5, || {
            let cfg = SimConfig { seed: 7, event_queue: kind, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone())
                .run()
        });
        stats.report(name);
        emit_bench_json(
            BIN,
            name,
            &[
                ("mean_s", stats.mean_s),
                ("events", events as f64),
                ("events_per_s", events as f64 / stats.mean_s.max(1e-12)),
            ],
        );
    }

    // ISSUE 4: gantt recording off vs on — `record_gantt: false` is the
    // allocation-free big-sweep configuration; outcomes are bit-identical
    // either way (engine unit test), only the recording cost differs.
    for (name, record) in [
        ("engine/events_norecord @200 jobs", false),
        ("engine/events_record @200 jobs", true),
    ] {
        let trace = production_trace(7, 200);
        let events = {
            let cfg = SimConfig { seed: 7, record_gantt: record, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone())
                .run()
                .events_processed
        };
        let stats = bench(1, 5, || {
            let cfg = SimConfig { seed: 7, record_gantt: record, ..Default::default() };
            Simulator::new(cfg, InterGroupScheduler::new(PhaseModel::default()), trace.clone())
                .run()
        });
        stats.report(name);
        emit_bench_json(
            BIN,
            name,
            &[
                ("mean_s", stats.mean_s),
                ("events", events as f64),
                ("events_per_s", events as f64 / stats.mean_s.max(1e-12)),
            ],
        );
    }

    // ISSUE 4: the two-tier fleet series. The ≥10x acceptance pair runs
    // both tiers on the SAME 10k-job trace (exact at 100k is minutes of
    // wall-clock; set ROLLMUX_BENCH_EXACT_100K=1 to measure it anyway),
    // and the fluid tier alone demonstrates the 100k-job sweep point.
    let mk_cfg = |fidelity| SimConfig { seed: 7, fidelity, ..Default::default() };
    let mk_sched = || InterGroupScheduler::with_max_group_size(PhaseModel::default(), 8);
    let trace10k = fleet_trace(7, 10_000, 1.0);
    let (exact10, exact10_s) =
        timed(|| run_sim(mk_cfg(Fidelity::Exact), mk_sched(), trace10k.clone()));
    println!(
        "exact/fleet_10k: {exact10_s:.2}s wall, {} events",
        exact10.events_processed
    );
    emit_bench_json(
        BIN,
        "exact/fleet_10k",
        &[("wall_s", exact10_s), ("events", exact10.events_processed as f64)],
    );
    let (fluid10, fluid10_s) =
        timed(|| run_sim(mk_cfg(Fidelity::Fluid), mk_sched(), trace10k.clone()));
    println!(
        "fluid/fleet_10k: {fluid10_s:.2}s wall, {} events, {:.1}x vs exact",
        fluid10.events_processed,
        exact10_s / fluid10_s.max(1e-12)
    );
    emit_bench_json(
        BIN,
        "fluid/fleet_10k",
        &[
            ("wall_s", fluid10_s),
            ("events", fluid10.events_processed as f64),
            ("speedup_vs_exact", exact10_s / fluid10_s.max(1e-12)),
        ],
    );
    let trace100k = fleet_trace(7, 100_000, 1.0);
    let (fluid100, fluid100_s) =
        timed(|| run_sim(mk_cfg(Fidelity::Fluid), mk_sched(), trace100k.clone()));
    println!(
        "fluid/fleet_100k: {fluid100_s:.2}s wall, {} events, SLO {:.3}",
        fluid100.events_processed,
        fluid100.slo_attainment()
    );
    emit_bench_json(
        BIN,
        "fluid/fleet_100k",
        &[("wall_s", fluid100_s), ("events", fluid100.events_processed as f64)],
    );

    // ISSUE 5: the chaos series — the same fleet trace with failure
    // injection (MTBF 1h, default crash/straggler mix). Measures the
    // overhead the fault layer adds to the fluid fast path at 10k and
    // 100k jobs (the fault-free numbers above are the baseline).
    let mk_chaos_cfg = |fidelity| SimConfig {
        seed: 7,
        fidelity,
        faults: Some(FaultConfig::with_mtbf(7, 3600.0)),
        ..Default::default()
    };
    let (chaos10, chaos10_s) =
        timed(|| run_sim(mk_chaos_cfg(Fidelity::Fluid), mk_sched(), trace10k.clone()));
    println!(
        "fluid/chaos_10k: {chaos10_s:.2}s wall, {} events, {} crashes, goodput {:.3}",
        chaos10.events_processed,
        chaos10.crashes,
        chaos10.goodput_frac()
    );
    emit_bench_json(
        BIN,
        "fluid/chaos_10k",
        &[
            ("wall_s", chaos10_s),
            ("events", chaos10.events_processed as f64),
            ("crashes", chaos10.crashes as f64),
            ("overhead_vs_faultfree", chaos10_s / fluid10_s.max(1e-12)),
        ],
    );
    let (chaos100, chaos100_s) =
        timed(|| run_sim(mk_chaos_cfg(Fidelity::Fluid), mk_sched(), trace100k.clone()));
    println!(
        "fluid/chaos_100k: {chaos100_s:.2}s wall, {} events, {} crashes, goodput {:.3}",
        chaos100.events_processed,
        chaos100.crashes,
        chaos100.goodput_frac()
    );
    emit_bench_json(
        BIN,
        "fluid/chaos_100k",
        &[
            ("wall_s", chaos100_s),
            ("events", chaos100.events_processed as f64),
            ("crashes", chaos100.crashes as f64),
            ("overhead_vs_faultfree", chaos100_s / fluid100_s.max(1e-12)),
        ],
    );
    let (exact_chaos, exact_chaos_s) = timed(|| {
        run_sim(
            mk_chaos_cfg(Fidelity::Exact),
            mk_sched(),
            fleet_trace(7, 2_000, 1.0),
        )
    });
    println!(
        "exact/chaos_2k: {exact_chaos_s:.2}s wall, {} events, {} crashes",
        exact_chaos.events_processed,
        exact_chaos.crashes
    );
    emit_bench_json(
        BIN,
        "exact/chaos_2k",
        &[
            ("wall_s", exact_chaos_s),
            ("events", exact_chaos.events_processed as f64),
            ("crashes", exact_chaos.crashes as f64),
        ],
    );
    // ISSUE 7 gen-7 acceptance pair: the group-parallel exact engine vs
    // the serial loop on the SAME fleet trace (results are bit-identical
    // — `prop_shard_equivalence` gates that; this measures only wall
    // time). The acceptance bar is >= 3x at 8 workers on the 100k-job
    // trace (EXPERIMENTS.md §scale). ROLLMUX_BENCH_PAR_JOBS shrinks the
    // trace for quick local runs without renaming the series.
    {
        let par_jobs = std::env::var("ROLLMUX_BENCH_PAR_JOBS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(100_000);
        let workers = 8usize;
        let trace_par = fleet_trace(7, par_jobs, 1.0);
        let (serial, serial_s) =
            timed(|| run_sim(mk_cfg(Fidelity::Exact), mk_sched(), trace_par.clone()));
        let (parallel, parallel_s) = timed(|| {
            let mut sim = Simulator::new(mk_cfg(Fidelity::Exact), mk_sched(), trace_par.clone());
            sim.run_parallel(workers)
        });
        assert_eq!(
            serial.makespan_s.to_bits(),
            parallel.makespan_s.to_bits(),
            "parallel engine diverged from serial"
        );
        assert_eq!(serial.events_processed, parallel.events_processed);
        let speedup = serial_s / parallel_s.max(1e-12);
        println!(
            "scale/engine_parallel_100k: serial {serial_s:.2}s vs parallel {parallel_s:.2}s \
             ({speedup:.2}x at {workers} workers, {par_jobs} jobs, {} events)",
            serial.events_processed
        );
        emit_bench_json(
            BIN,
            "scale/engine_parallel_100k",
            &[
                ("serial_wall_s", serial_s),
                ("parallel_wall_s", parallel_s),
                ("speedup", speedup),
                ("workers", workers as f64),
                ("jobs", par_jobs as f64),
                ("events", serial.events_processed as f64),
            ],
        );
    }

    if std::env::var("ROLLMUX_BENCH_EXACT_100K").is_ok_and(|v| v == "1") {
        let (exact100, exact100_s) =
            timed(|| run_sim(mk_cfg(Fidelity::Exact), mk_sched(), trace100k));
        println!(
            "exact/fleet_100k: {exact100_s:.2}s wall, {} events, {:.1}x slower than fluid",
            exact100.events_processed,
            exact100_s / fluid100_s.max(1e-12)
        );
        emit_bench_json(
            BIN,
            "exact/fleet_100k",
            &[
                ("wall_s", exact100_s),
                ("events", exact100.events_processed as f64),
                ("slowdown_vs_fluid", exact100_s / fluid100_s.max(1e-12)),
            ],
        );
    }
}
