//! Bench: sync planning + residency ledger + switch model micro-costs
//! (all sit on the scheduler's per-decision path).

use rollmux::memory::{cold_start_s, warm_start_s, ResidencyLedger};
use rollmux::cluster::node::PoolKind;
use rollmux::sync::{plan::plan_sync, topology::NetworkTopology, SyncScheme};
use rollmux::util::bench;

fn main() {
    println!("== sync_and_memory ==");
    let topo = NetworkTopology::default();
    let stats = bench(100, 10_000, || {
        plan_sync(SyncScheme::Hierarchical, 28e9, 16, 64, &topo).time_s
    });
    stats.report("sync/plan_hierarchical");
    let stats = bench(100, 10_000, || {
        (cold_start_s(14.0, PoolKind::Train), warm_start_s(14.0, PoolKind::Rollout))
    });
    stats.report("memory/switch_model");
    let stats = bench(10, 2_000, || {
        let mut l = ResidencyLedger::new(2048.0);
        for j in 0..16 {
            l.pin(j % 4, j, 240.0);
        }
        for j in 0..16 {
            l.unpin(j % 4, j);
        }
        l.check_invariant()
    });
    stats.report("memory/residency_ledger 16 pin/unpin");
}
