//! Bench: inter-group scheduling decision latency (paper Table 5).
//!
//! Measures Algorithm 1's per-decision latency as the number of live jobs
//! grows, the sustained placement throughput of the 2000-job regression
//! workload, plus the brute-force optimal solver at small sizes.
//! Criterion is unavailable offline; this uses the in-tree harness
//! (util::bench). Set BENCH_JSON_OUT (scripts/bench.sh does) to collect
//! machine-readable records for BENCH_1.json.

use rollmux::baselines::optimal::optimal_partition_deadline;
use rollmux::cluster::PhaseModel;
use rollmux::coordinator::inter::InterGroupScheduler;
use rollmux::util::{bench, bench_with_setup, emit_bench_json, rng::Rng, timed};
use rollmux::workload::job::{JobSpec, PhaseSpec};
use rollmux::workload::profiles::{table6_job, SimProfile};

const BIN: &str = "scheduler_latency";

fn main() {
    println!("== scheduler_latency (Table 5) ==");
    let model = PhaseModel::default();
    for &n in &[5usize, 9, 13, 100, 500, 1000, 2000] {
        let mut rng = Rng::new(7);
        let jobs: Vec<_> = (0..n)
            .map(|id| {
                let slo = rng.uniform(1.0, 2.0);
                table6_job(id, SimProfile::Mixed, &mut rng, slo, 0.0, 5)
            })
            .collect();
        let mut sched = InterGroupScheduler::new(model);
        for j in &jobs {
            sched.schedule(j.clone());
        }
        // Time ONLY the marginal decision: the per-run state clone happens
        // in the setup phase and is returned from the run so its teardown
        // is also outside the samples (Table 5 methodology).
        let mut k = 0usize;
        let stats = bench_with_setup(
            2,
            if n >= 1000 { 8 } else { 30 },
            || {
                let slo = rng.uniform(1.0, 2.0);
                let probe = table6_job(n + k, SimProfile::Mixed, &mut rng, slo, 0.0, 5);
                k += 1;
                (sched.clone(), probe)
            },
            |(mut s2, probe)| {
                let d = s2.schedule(probe);
                (s2, d)
            },
        );
        stats.report_json(BIN, &format!("algorithm1/decide @{n} jobs"), 1.0);
    }

    // Sustained throughput on the regression-gate workload: 2000
    // placements from an empty cluster (matches the
    // `decisions_scale_linearly` test trace).
    let mk_job = |id: usize| JobSpec {
        id,
        name: format!("j{id}"),
        arrival_s: 0.0,
        n_iters: 10,
        slo: 1.0 + (id % 10) as f64 / 10.0,
        n_roll_gpus: 8,
        n_train_gpus: 8,
        params_b: 7.0,
        phases: PhaseSpec::Direct {
            t_roll: 50.0 + (id % 17) as f64 * 20.0,
            t_train: 40.0 + (id % 13) as f64 * 25.0,
            cv: 0.0,
        },
    };
    let (groups, secs) = timed(|| {
        let mut s = InterGroupScheduler::new(model);
        for id in 0..2000 {
            s.schedule(mk_job(id));
        }
        s.groups.len()
    });
    println!(
        "algorithm1/place_2000_from_empty: {:.3}s wall, {} groups, {:.0} placements/s",
        secs,
        groups,
        2000.0 / secs
    );
    emit_bench_json(
        BIN,
        "algorithm1/place_2000_from_empty",
        &[("wall_s", secs), ("placements_per_s", 2000.0 / secs), ("groups", groups as f64)],
    );

    // ISSUE 3 fleet-scale acceptance: 20k placements through the indexed
    // scheduler vs the exhaustive reference scan (the pre-PR 3 decision
    // path, kept as `schedule_reference`). Decisions are property-tested
    // bit-identical; the acceptance bar is >= 5x placements/s
    // (EXPERIMENTS.md §Perf PR 3). The job mix reuses the regression-gate
    // shape so both runs build the same fleet.
    const FLEET: usize = 20_000;
    let (groups, secs) = timed(|| {
        let mut s = InterGroupScheduler::new(model);
        for id in 0..FLEET {
            s.schedule(mk_job(id));
        }
        s.groups.len()
    });
    println!(
        "algorithm1/place_20k_indexed: {:.3}s wall, {} groups, {:.0} placements/s",
        secs,
        groups,
        FLEET as f64 / secs
    );
    emit_bench_json(
        BIN,
        "algorithm1/place_20k_indexed",
        &[
            ("wall_s", secs),
            ("placements_per_s", FLEET as f64 / secs),
            ("groups", groups as f64),
        ],
    );
    let (groups_ref, secs_ref) = timed(|| {
        let mut s = InterGroupScheduler::new(model);
        for id in 0..FLEET {
            s.schedule_reference(mk_job(id));
        }
        s.groups.len()
    });
    assert_eq!(groups, groups_ref, "indexed and reference scans must agree");
    println!(
        "algorithm1/place_20k_reference: {:.3}s wall, {:.0} placements/s, speedup {:.2}x",
        secs_ref,
        FLEET as f64 / secs_ref,
        secs_ref / secs
    );
    emit_bench_json(
        BIN,
        "algorithm1/place_20k_reference",
        &[
            ("wall_s", secs_ref),
            ("placements_per_s", FLEET as f64 / secs_ref),
            // The acceptance ratio: how many times faster the indexed
            // path is than this reference scan (>= 5 required).
            ("speedup_indexed_over_reference", secs_ref / secs),
        ],
    );

    // ISSUE 7 gen-7: the sharded placement scan vs the single-shard
    // indexed path on the same 20k-job fleet build-up. Decisions are
    // property-tested bit-identical to `schedule_reference`
    // (rust/tests/prop_shard_equivalence.rs); only wall time may differ.
    let place_sharded = |shards: usize| {
        timed(|| {
            let mut s = InterGroupScheduler::with_shards(model, shards);
            for id in 0..FLEET {
                s.schedule(mk_job(id));
            }
            s.groups.len()
        })
    };
    let (groups_s1, secs_s1) = place_sharded(1);
    let (groups_s8, secs_s8) = place_sharded(8);
    assert_eq!(groups_s1, groups_s8, "sharded and single-shard scans must agree");
    println!(
        "scale/placement_sharded_20k: 1 shard {:.3}s vs 8 shards {:.3}s \
         ({:.2}x, {:.0} placements/s sharded)",
        secs_s1,
        secs_s8,
        secs_s1 / secs_s8.max(1e-12),
        FLEET as f64 / secs_s8
    );
    emit_bench_json(
        BIN,
        "scale/placement_sharded_20k",
        &[
            ("wall_s_1shard", secs_s1),
            ("wall_s_8shards", secs_s8),
            ("placements_per_s", FLEET as f64 / secs_s8),
            ("speedup_8_over_1", secs_s1 / secs_s8.max(1e-12)),
            ("groups", groups_s8 as f64),
        ],
    );

    // Brute force for reference (paper: 113 ms @5, >1 min @9, >5 h @13).
    for &n in &[5usize, 7, 9] {
        let mut rng = Rng::new(7);
        let jobs: Vec<_> = (0..n)
            .map(|id| {
                let slo = rng.uniform(1.0, 2.0);
                table6_job(id, SimProfile::Mixed, &mut rng, slo, 0.0, 5)
            })
            .collect();
        let stats = bench(0, 3, || optimal_partition_deadline(&jobs, &model, 20.0));
        stats.report_json(BIN, &format!("brute_force/partition @{n} jobs"), 1.0);
    }
}
