//! Bench: inter-group scheduling decision latency (paper Table 5).
//!
//! Measures Algorithm 1's per-decision latency as the number of live jobs
//! grows, plus the brute-force optimal solver at small sizes. Criterion is
//! unavailable offline; this uses the in-tree harness (util::bench).

use rollmux::baselines::optimal::optimal_partition_deadline;
use rollmux::cluster::PhaseModel;
use rollmux::coordinator::inter::InterGroupScheduler;
use rollmux::util::{bench, rng::Rng};
use rollmux::workload::profiles::{table6_job, SimProfile};

fn main() {
    println!("== scheduler_latency (Table 5) ==");
    let model = PhaseModel::default();
    for &n in &[5usize, 9, 13, 100, 500, 1000, 2000] {
        let mut rng = Rng::new(7);
        let jobs: Vec<_> = (0..n)
            .map(|id| {
                let slo = rng.uniform(1.0, 2.0);
                table6_job(id, SimProfile::Mixed, &mut rng, slo, 0.0, 5)
            })
            .collect();
        let mut sched = InterGroupScheduler::new(model);
        for j in &jobs {
            sched.schedule(j.clone());
        }
        let mut k = 0usize;
        let stats = bench(2, if n >= 1000 { 8 } else { 30 }, || {
            let slo = rng.uniform(1.0, 2.0);
            let probe = table6_job(n + k, SimProfile::Mixed, &mut rng, slo, 0.0, 5);
            k += 1;
            let mut s2 = sched.clone();
            s2.schedule(probe)
        });
        stats.report(&format!("algorithm1/decide @{n} jobs"));
    }
    // Brute force for reference (paper: 113 ms @5, >1 min @9, >5 h @13).
    for &n in &[5usize, 7, 9] {
        let mut rng = Rng::new(7);
        let jobs: Vec<_> = (0..n)
            .map(|id| {
                let slo = rng.uniform(1.0, 2.0);
                table6_job(id, SimProfile::Mixed, &mut rng, slo, 0.0, 5)
            })
            .collect();
        let stats = bench(0, 3, || optimal_partition_deadline(&jobs, &model, 20.0));
        stats.report(&format!("brute_force/partition @{n} jobs"));
    }
}
