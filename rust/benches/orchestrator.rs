//! Bench: dispatch throughput of the extracted orchestration core
//! (ISSUE 2) — one enqueue → policy pick → occupy → release round-trip
//! per phase, the per-event cost both the simulator and the wall-clock
//! driver pay. Set BENCH_JSON_OUT (scripts/bench.sh does) to collect
//! machine-readable records for BENCH_2.json.

use rollmux::coordinator::orchestrator::{CorePhase, GroupOrchestrator, IntraPolicyKind};
use rollmux::util::bench;

const BIN: &str = "orchestrator";
const CYCLES: usize = 200;

fn main() {
    println!("== orchestrator ==");
    for kind in IntraPolicyKind::all() {
        for &n_jobs in &[4usize, 16, 64] {
            // Half as many nodes as jobs: every cycle mixes immediate
            // grants with queueing, like a packed co-execution group.
            let n_nodes = (n_jobs / 2).max(1);
            let stats = bench(2, 10, || {
                let mut orc = GroupOrchestrator::new(kind);
                for slot in 0..n_jobs {
                    orc.admit(slot, slot, vec![slot % n_nodes], 100.0 + slot as f64);
                }
                let mut dispatched = 0usize;
                for _ in 0..CYCLES {
                    for slot in 0..n_jobs {
                        orc.enqueue(slot, CorePhase::Rollout);
                    }
                    while let Some(st) = orc.next_dispatch() {
                        orc.release_rollout(st.slot);
                        dispatched += 1;
                    }
                    for slot in 0..n_jobs {
                        orc.enqueue(slot, CorePhase::Train);
                    }
                    while let Some(st) = orc.next_dispatch() {
                        orc.release_train(st.slot);
                        dispatched += 1;
                    }
                }
                assert_eq!(dispatched, CYCLES * n_jobs * 2);
                dispatched
            });
            stats.report_json(
                BIN,
                &format!("dispatch/{} @{n_jobs} jobs", kind.name()),
                (CYCLES * n_jobs * 2) as f64,
            );
        }
    }
}
