//! Bench: forensic observability (ISSUE 10, DESIGN.md §18).
//! `decisions_overhead` is the acceptance series: arming
//! `record_decisions` on a flight-recorded chaos fleet run must cost
//! <= 5% wall time over recording-off. Also: RMTRC01 archive
//! encode/decode throughput and `slo-breach` query throughput on a
//! ~100k-frame chaos archive.
//! Set BENCH_JSON_OUT (scripts/bench.sh does) for BENCH_10.json records.

use rollmux::cluster::PhaseModel;
use rollmux::coordinator::inter::InterGroupScheduler;
use rollmux::obs::query as q;
use rollmux::obs::FlightArchive;
use rollmux::sim::engine::{SimConfig, Simulator};
use rollmux::sim::faults::FaultConfig;
use rollmux::util::{bench, emit_bench_json, timed};
use rollmux::workload::trace::fleet_trace;

const BIN: &str = "obs";
const N_JOBS: usize = 1_000;

fn chaos() -> FaultConfig {
    FaultConfig {
        seed: 13,
        mtbf_s: 2.0 * 3600.0,
        mean_repair_s: 600.0,
        straggler_frac: 0.3,
        straggler_factor: 1.4,
        max_events: 40,
    }
}

fn main() {
    println!("== obs ==");
    let base = SimConfig {
        seed: 7,
        record_flight: true,
        faults: Some(chaos()),
        ..Default::default()
    };
    let armed = SimConfig { record_decisions: true, ..base.clone() };
    let mk_sched = || InterGroupScheduler::with_max_group_size(PhaseModel::default(), 8);
    let trace = fleet_trace(7, N_JOBS, 1.0);

    // decisions_overhead: the acceptance series — provenance capture on
    // a run that already pays for the flight recorder.
    let (off, off_s) = timed(|| {
        Simulator::new(base.clone(), mk_sched(), trace.clone()).run_to_end()
    });
    let (on, on_s) = timed(|| {
        Simulator::new(armed.clone(), mk_sched(), trace.clone()).run_to_end()
    });
    let overhead = on_s / off_s.max(1e-12) - 1.0;
    println!(
        "decisions_overhead: off {off_s:.2}s vs on {on_s:.2}s ({:+.1}%, {} -> {} frames)",
        overhead * 100.0,
        off.flight.len(),
        on.flight.len()
    );
    emit_bench_json(
        BIN,
        "decisions_overhead",
        &[
            ("off_wall_s", off_s),
            ("on_wall_s", on_s),
            ("overhead_frac", overhead),
            ("frames", on.flight.len() as f64),
        ],
    );

    // Archive codec throughput on the armed run's frame stream.
    let frames = on.flight.frames();
    let bytes = FlightArchive::encode(frames);
    println!("archive footprint: {} frames, {} KiB", frames.len(), bytes.len() / 1024);
    let enc = bench(1, 10, || FlightArchive::encode(frames));
    enc.report_json(BIN, "encode_archive", bytes.len() as f64);
    let dec = bench(1, 10, || FlightArchive::decode(&bytes).expect("decode"));
    dec.report_json(BIN, "decode_archive", bytes.len() as f64);

    // Query throughput over the decoded archive (the CLI's hot path).
    let decoded = FlightArchive::decode(&bytes).expect("decode");
    let slo = bench(1, 10, || q::slo_breach(&decoded, 600.0));
    slo.report_json(BIN, "slo_breach_query", decoded.len() as f64);
    let bub = bench(1, 10, || q::bubbles(&decoded));
    bub.report_json(BIN, "bubbles_query", decoded.len() as f64);
    let hist = bench(1, 10, || q::histograms(&decoded));
    hist.report_json(BIN, "histograms_query", decoded.len() as f64);
}
