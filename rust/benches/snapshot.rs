//! Bench: checkpoint capture, restore, and the branch-from-t fork sweep
//! (ISSUE 9, DESIGN.md §17) on the 2k-job exact-engine fleet trace.
//! `fork_sweep_vs_rerun` is the acceptance series: 8 what-if branches
//! off one shared checkpoint must beat 8 independent re-runs by >= 3x
//! (the same inner loop `rollmux exp replay` verifies bitwise).
//! Set BENCH_JSON_OUT (scripts/bench.sh does) for BENCH_9.json records.

use rollmux::cluster::PhaseModel;
use rollmux::coordinator::inter::InterGroupScheduler;
use rollmux::coordinator::orchestrator::IntraPolicyKind;
use rollmux::sim::engine::{SimConfig, SimSnapshot, Simulator};
use rollmux::util::{bench, emit_bench_json, timed};
use rollmux::workload::trace::fleet_trace;

const BIN: &str = "snapshot";
const N_JOBS: usize = 2_000;
const BRANCHES: usize = 8;

fn main() {
    println!("== snapshot ==");
    let cfg = SimConfig { seed: 7, record_flight: true, ..Default::default() };
    let mk_sched = || InterGroupScheduler::with_max_group_size(PhaseModel::default(), 8);
    let trace = fleet_trace(7, N_JOBS, 1.0);
    let mk_sim = || Simulator::new(cfg.clone(), mk_sched(), trace.clone());

    // Baseline: one full run fixes the fork points and the re-run cost.
    let (base, base_s) = timed(|| mk_sim().run_to_end());
    println!("baseline/fleet_2k: {base_s:.2}s wall, {} events", base.events_processed);
    emit_bench_json(
        BIN,
        "baseline/fleet_2k",
        &[("wall_s", base_s), ("events", base.events_processed as f64)],
    );

    // snapshot_2k: capture cost mid-run (clock at 50% of makespan).
    let mut prefix = mk_sim();
    let snap = prefix.fork_at(base.makespan_s * 0.5);
    let stats = bench(1, 10, || prefix.snapshot());
    stats.report_json(BIN, "snapshot_2k", snap.live_jobs() as f64);

    // Byte codec on the same checkpoint: encode + decode wall time and
    // the on-disk footprint.
    let bytes = snap.to_bytes();
    let enc = bench(1, 10, || snap.to_bytes());
    enc.report_json(BIN, "encode_2k", bytes.len() as f64);
    let dec = bench(1, 10, || SimSnapshot::from_bytes(&bytes).expect("decode"));
    dec.report_json(BIN, "decode_2k", bytes.len() as f64);
    println!("checkpoint footprint: {} KiB", bytes.len() / 1024);

    // restore_2k: rebuild a live simulator from the checkpoint.
    let res = bench(1, 10, || Simulator::restore(cfg.clone(), &trace, &snap));
    res.report_json(BIN, "restore_2k", snap.live_jobs() as f64);

    // fork_sweep_vs_rerun: 8 branches off ONE late checkpoint (90% of
    // makespan, where forking pays) vs 8 independent from-scratch runs
    // applying the same divergence. Acceptance: >= 3x.
    let t_fork = base.makespan_s * 0.9;
    let policies = IntraPolicyKind::all();
    let diverge = |sim: &mut Simulator<InterGroupScheduler>, branch: usize| {
        if branch > 0 {
            sim.set_intra_policy(policies[branch % policies.len()]);
        }
    };
    let (late_snap, prefix_s) = timed(|| mk_sim().fork_at(t_fork));
    let mut fork_total = prefix_s;
    let mut rerun_total = 0.0;
    for branch in 0..BRANCHES {
        let (_, fork_s) = timed(|| {
            let mut sim = Simulator::restore(cfg.clone(), &trace, &late_snap);
            diverge(&mut sim, branch);
            sim.run_to_end()
        });
        let (_, rerun_s) = timed(|| {
            let mut sim = mk_sim();
            sim.run_until(t_fork);
            diverge(&mut sim, branch);
            sim.run_to_end()
        });
        fork_total += fork_s;
        rerun_total += rerun_s;
    }
    let speedup = rerun_total / fork_total.max(1e-12);
    println!(
        "fork_sweep_vs_rerun: fork {fork_total:.2}s vs rerun {rerun_total:.2}s \
         ({speedup:.2}x, {BRANCHES} branches at 90% fork point)"
    );
    emit_bench_json(
        BIN,
        "fork_sweep_vs_rerun",
        &[
            ("fork_wall_s", fork_total),
            ("rerun_wall_s", rerun_total),
            ("speedup", speedup),
            ("branches", BRANCHES as f64),
            ("jobs", N_JOBS as f64),
        ],
    );
}
