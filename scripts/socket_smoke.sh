#!/usr/bin/env bash
# Socket transport smoke (ISSUE 8, DESIGN.md §16): drive `rollmux serve
# --listen <unix-path>` with two concurrent JSONL clients from one
# python3 driver.
#
# Leg 1 — determinism: the full two-tenant session (subscribe, admits,
# live reconfig with an event push, drain with `done` pushes, shutdown)
# runs under ROLLMUX_THREADS=1 and ROLLMUX_THREADS=4; the client-side
# transcripts must be byte-identical. Thread count may only change wall
# time, never a response byte.
#
# Leg 2 — crash recovery: the session's journaled prefix (subscribe +
# both admits, --sync-every 1 so every accepted frame is durable) ends
# with the CLIENT delivering kill -9 to the daemon while tenant 1's
# subscription is still live on the wire. A restarted daemon replays
# the journal (subscription and tenant base included — fresh
# connections get ids past everything replayed) and absorbs the
# remainder of the session; its drained accounting line must be
# byte-identical to the uninterrupted run's. The journaled merged order
# IS the semantics.
#
# Usage: scripts/socket_smoke.sh
#   ROLLMUX_BIN=path   override the rollmux binary under test
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BIN="${ROLLMUX_BIN:-$ROOT/target/release/rollmux}"
WORK="$(mktemp -d)"
SRV_PID=""
cleanup() {
    [[ -n "$SRV_PID" ]] && kill -9 "$SRV_PID" 2>/dev/null || true
    rm -rf "$WORK"
}
trap cleanup EXIT

CLIENT="$WORK/client.py"
cat > "$CLIENT" <<'PY'
"""Two-tenant JSONL client for the rollmuxd socket smoke.

Modes:
  full    whole session; prints each received line tagged A/B
  prefix  subscribe + admits, then kill -9 the server (pid in argv[3])
          with the subscription still live on the wire
  tail    reconnect after restart and feed the session's remainder
"""
import os
import socket
import sys
import time

sock_path, mode = sys.argv[1], sys.argv[2]
srv_pid = int(sys.argv[3]) if len(sys.argv) > 3 else 0


def connect():
    deadline = time.time() + 10.0
    while True:
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            s.connect(sock_path)
            return s, s.makefile("r", encoding="utf-8")
        except OSError:
            s.close()
            if time.time() > deadline:
                raise SystemExit(f"connect {sock_path}: timed out")
            time.sleep(0.02)


def say(tag, line):
    sys.stdout.write(f"{tag} {line}\n")


def roundtrip(tag, s, r, cmd, expect):
    s.sendall(cmd.encode() + b"\n")
    line = r.readline().strip()
    assert expect in line, f"{tag}: sent {cmd!r}, got {line!r}"
    say(tag, line)
    return line


def admit(i):
    return (
        '{"cmd":"admit","job":{"id":%d,"n_iters":2,"slo":3.0,'
        '"n_roll_gpus":8,"n_train_gpus":8,"params_b":7.0,'
        '"t_roll":60.0,"t_train":40.0}}' % i
    )


if mode in ("full", "prefix"):
    # A's awaited subscribe ack pins it as the first accepted tenant
    # before B ever connects.
    a, ar = connect()
    roundtrip("A", a, ar, '{"cmd":"subscribe"}', '"ok":"subscribe"')
    b, br = connect()
    roundtrip("B", b, br, admit(0), '"ok":"admit"')
    roundtrip("A", a, ar, admit(1), '"ok":"admit"')

if mode == "prefix":
    # Every acked command is already durable (--sync-every 1); take the
    # daemon down hard, no unsub, no drain.
    os.kill(srv_pid, 9)
    sys.exit(0)

if mode == "tail":
    # Fresh connections after the restart: the replayed daemon hands
    # out tenant ids past the journaled ones, and tenant 1's replayed
    # subscription points at no live socket — pushes to it are counted
    # in the journaled stats but dropped by the transport.
    a, ar = connect()
    b, br = connect()

roundtrip("B", b, br, '{"cmd":"reconfig","gpu_cap":64}', '"ok":"reconfig"')
if mode == "full":
    ev = ar.readline().strip()
    assert '"event":"reconfig"' in ev, ev
    say("A", ev)

a.sendall(b'{"cmd":"drain"}\n')
drained = ar.readline().strip()
assert '"drained"' in drained, drained
say("A", drained)
if mode == "full":
    for _ in range(2):
        ev = ar.readline().strip()
        assert '"event":"done"' in ev, ev
        say("A", ev)

roundtrip("B", b, br, '{"cmd":"shutdown"}', '"ok":"shutdown"')
PY

start_server() { # $1 threads, $2 journal, $3 socket, $4 stderr log
    ROLLMUX_THREADS="$1" "$BIN" serve --virtual --listen "$3" \
        --journal "$2" --sync-every 1 2>"$4" &
    SRV_PID=$!
}

stop_server() { # $1 stderr log shown on a dirty exit
    local rc=0
    wait "$SRV_PID" || rc=$?
    SRV_PID=""
    if [[ "$rc" -ne 0 ]]; then
        echo "socket_smoke: server exited rc=$rc" >&2
        cat "$1" >&2
        exit 1
    fi
}

echo "== leg 1: two-tenant session is thread-count invariant =="
for t in 1 4; do
    start_server "$t" "$WORK/full_t${t}.wal" "$WORK/t${t}.sock" "$WORK/full_t${t}.err"
    python3 "$CLIENT" "$WORK/t${t}.sock" full > "$WORK/full_t${t}.out"
    stop_server "$WORK/full_t${t}.err"
done
diff "$WORK/full_t1.out" "$WORK/full_t4.out"
echo "ok: transcripts byte-identical under ROLLMUX_THREADS={1,4}"

echo "== leg 2: kill -9 mid-session, journaled restart =="
start_server 4 "$WORK/crash.wal" "$WORK/crash.sock" "$WORK/prefix.err"
python3 "$CLIENT" "$WORK/crash.sock" prefix "$SRV_PID" > "$WORK/prefix.out"
wait "$SRV_PID" 2>/dev/null || true # killed: nonzero by design
SRV_PID=""

start_server 4 "$WORK/crash.wal" "$WORK/crash.sock" "$WORK/tail.err"
python3 "$CLIENT" "$WORK/crash.sock" tail > "$WORK/tail.out"
stop_server "$WORK/tail.err"

grep -F '"drained"' "$WORK/full_t1.out" > "$WORK/drained_want.txt"
grep -F '"drained"' "$WORK/tail.out" > "$WORK/drained_got.txt"
diff "$WORK/drained_want.txt" "$WORK/drained_got.txt"
echo "ok: drained accounting survives kill -9 + replay byte-for-byte"
