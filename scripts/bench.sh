#!/usr/bin/env bash
# Run the performance bench binaries and assemble the machine-readable
# BENCH_N.json at the repository root (the perf trajectory is tracked
# across PRs; see EXPERIMENTS.md §Perf for methodology). ISSUE 1
# produced BENCH_1.json, ISSUE 2 BENCH_2.json; the generation is a
# parameter so each PR appends its own file instead of editing this
# script (ISSUE 10 default: BENCH_10.json).
#
# Multi-round protocol (ISSUE 7): the whole bench suite runs
# BENCH_ROUNDS times (default 5) plus ONE warmup round that is
# discarded (page cache, CPU governor, JIT-less but still branch
# predictors). Each (bench, name) entry in the output carries the
# per-metric MEDIAN across the kept rounds plus a `cv` field — the
# coefficient of variation of the entry's decisive metric — so a
# reader can judge how trustworthy each number is. Entries gated by
# scripts/bench_compare.sh (rate metrics / mean_s) must satisfy
# cv <= MAX_CV (default 0.15) or this script FAILS: a machine too noisy
# to measure on must not mint a trajectory point. Single-sample wall_s
# entries are reported with their cv but never gated (matching
# bench_compare.sh's policy).
#
# Usage: scripts/bench.sh [gen] [extra cargo args...]
#   gen                 bench generation number (default: 10 -> BENCH_10.json)
#   BENCH_OUT=path      override the output file entirely
#   BENCH_ROUNDS=n      kept measurement rounds (default 5; warmup extra)
#   MAX_CV=x            acceptance ceiling on gated entries' cv (default 0.15)
#   ROLLMUX_BENCH_PAR_JOBS=n   shrink the scale/engine_parallel_100k trace
#                              for quick local runs (CI uses the default)
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
GEN="10"
if [[ $# -ge 1 && "$1" =~ ^[0-9]+$ ]]; then
    GEN="$1"
    shift
fi
OUT="${BENCH_OUT:-$ROOT/BENCH_${GEN}.json}"
ROUNDS="${BENCH_ROUNDS:-5}"
MAX_CV="${MAX_CV:-0.15}"
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

cd "$ROOT"
# Build once so the rounds time execution, not compilation.
cargo bench --no-run "$@"

run_suite() {
    # ISSUE 3: scheduler_latency includes the 20k-job fleet-scale
    # placement benches (indexed vs exhaustive reference — the >= 5x
    # acceptance pair); ISSUE 7 adds scale/placement_sharded_20k (the
    # sharded scan vs one shard). simulator carries the events/s engine
    # benches, the ISSUE 4 two-tier fleet series, the ISSUE 5 chaos
    # series, and the ISSUE 7 scale/engine_parallel_100k serial-vs-
    # parallel acceptance pair (>= 3x at 8 workers).
    cargo bench --bench scheduler_latency "$@"
    cargo bench --bench simulator "$@"
    # ISSUE 2: dispatch throughput of the extracted orchestration core.
    cargo bench --bench orchestrator "$@"
    # sync_and_memory measures per-decision micro-costs; cheap, keep it.
    cargo bench --bench sync_and_memory "$@" || true
    # ISSUES 6, 8: rollmuxd control-plane series (admission, journal,
    # replay, live reconfig, multi-tenant arbiter path).
    cargo bench --bench daemon "$@"
    # ISSUE 9: checkpoint capture/codec/restore costs plus the
    # fork_sweep_vs_rerun acceptance pair (>= 3x for 8 branches off one
    # late checkpoint vs 8 independent re-runs).
    cargo bench --bench snapshot "$@"
    # ISSUE 10: decision-provenance recording overhead (acceptance
    # <= 5% over recording-off), RMTRC01 archive codec throughput, and
    # trace-query throughput on a chaos archive.
    cargo bench --bench obs "$@"
}

echo "== bench round 0/${ROUNDS} (warmup, discarded) =="
BENCH_JSON_OUT="$TMP/warmup.jsonl" run_suite "$@"

for r in $(seq 1 "$ROUNDS"); do
    echo "== bench round ${r}/${ROUNDS} =="
    BENCH_JSON_OUT="$TMP/round_${r}.jsonl" run_suite "$@"
    if [[ ! -s "$TMP/round_${r}.jsonl" ]]; then
        echo "error: round ${r} produced no records" >&2
        exit 1
    fi
done

GIT_REV="$(git -C "$ROOT" rev-parse --short HEAD 2>/dev/null || echo unknown)"
python3 - "$TMP" "$ROUNDS" "$OUT" "$GIT_REV" "$MAX_CV" <<'PY'
import json
import statistics
import sys

tmp, rounds, out_path, git_rev, max_cv = (
    sys.argv[1], int(sys.argv[2]), sys.argv[3], sys.argv[4], float(sys.argv[5]))

# Metrics bench_compare.sh gates on (first present decides) — these must
# meet the cv ceiling. wall_s is trajectory data: reported, never gated.
GATED = ("ops_per_s", "events_per_s", "phases_per_s", "placements_per_s", "mean_s")

# rounds[i] maps (bench, name) -> entry; entry order follows round 1.
order = []
samples = {}
for r in range(1, rounds + 1):
    with open(f"{tmp}/round_{r}.jsonl") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            e = json.loads(line)
            key = (e.get("bench", ""), e.get("name", ""))
            if key not in samples:
                samples[key] = []
                order.append(key)
            samples[key].append(e)

noisy = []
entries = []
for key in order:
    runs = samples[key]
    merged = {"bench": key[0], "name": key[1]}
    numeric = {}
    for e in runs:
        for k, v in e.items():
            if k in ("bench", "name"):
                continue
            if isinstance(v, (int, float)):
                numeric.setdefault(k, []).append(float(v))
            else:
                merged.setdefault(k, v)
    for k, vals in numeric.items():
        merged[k] = statistics.median(vals)
    merged["rounds"] = len(runs)
    decisive = next((m for m in GATED if m in numeric), None)
    gated = decisive is not None
    if decisive is None:
        decisive = next((m for m in numeric if m.endswith("wall_s") or m == "wall_s"),
                        next(iter(numeric), None))
    if decisive is not None and len(numeric[decisive]) > 1:
        vals = numeric[decisive]
        mean = statistics.fmean(vals)
        cv = (statistics.stdev(vals) / mean) if mean else 0.0
        merged["cv"] = round(cv, 6)
        merged["cv_metric"] = decisive
        if gated and cv > max_cv:
            noisy.append((key, decisive, cv))
    entries.append(merged)

doc = {"schema": "rollmux-bench-v1", "git_rev": git_rev,
       "rounds": rounds, "max_cv": max_cv, "entries": entries}
with open(out_path, "w") as fh:
    json.dump(doc, fh, indent=1)
    fh.write("\n")
print(f"wrote {out_path} ({len(entries)} entries, median of {rounds} rounds)")

if noisy:
    for key, metric, cv in noisy:
        print(f"NOISY: {key[0]}/{key[1]}: {metric} cv {cv:.3f} > {max_cv}",
              file=sys.stderr)
    print(f"bench.sh: {len(noisy)} gated entries exceed MAX_CV={max_cv}; "
          "this machine is too noisy to mint a trajectory point",
          file=sys.stderr)
    sys.exit(1)
PY
