#!/usr/bin/env bash
# Run the performance bench binaries and assemble the machine-readable
# BENCH_N.json at the repository root (the perf trajectory is tracked
# across PRs; see EXPERIMENTS.md §Perf for methodology). ISSUE 1
# produced BENCH_1.json, ISSUE 2 BENCH_2.json; the generation is now a
# parameter so each PR appends its own file instead of editing this
# script (ISSUE 6 default: BENCH_6.json).
#
# Usage: scripts/bench.sh [gen] [extra cargo args...]
#   gen              bench generation number (default: 6 -> BENCH_6.json)
#   BENCH_OUT=path   override the output file entirely
#
# Each bench binary appends one JSON object per measurement to
# $BENCH_JSON_OUT (see util::emit_bench_json); this script wraps the
# collected lines into a single JSON document.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
GEN="6"
if [[ $# -ge 1 && "$1" =~ ^[0-9]+$ ]]; then
    GEN="$1"
    shift
fi
OUT="${BENCH_OUT:-$ROOT/BENCH_${GEN}.json}"
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT
export BENCH_JSON_OUT="$TMP/bench.jsonl"

cd "$ROOT"
# ISSUE 3: scheduler_latency now includes the 20k-job fleet-scale
# placement benches (indexed vs exhaustive reference — the >= 5x
# acceptance pair) and simulator the events/s engine benches (calendar
# queue vs binary heap). ISSUE 4 adds the gantt on/off events series and
# the two-tier fleet series (fluid/fleet_100k, fluid-vs-exact at 10k —
# the >= 10x acceptance pair). ISSUE 5 adds the chaos series
# (fluid/chaos_{10k,100k} + exact/chaos_2k: failure injection overhead
# vs the fault-free runs on the same traces; compare generations with
# scripts/bench_compare.sh, e.g. BENCH_4.json vs BENCH_5.json).
cargo bench --bench scheduler_latency "$@"
cargo bench --bench simulator "$@"
# ISSUE 2: dispatch throughput of the extracted orchestration core, per
# policy — keeps the refactor's hot path on the perf trajectory.
cargo bench --bench orchestrator "$@"
# sync_and_memory measures per-decision micro-costs; cheap, keep it in.
cargo bench --bench sync_and_memory "$@" || true
# ISSUE 6: rollmuxd control-plane series — admission throughput (bare
# and journaled) and cold-start journal replay (crash recovery).
cargo bench --bench daemon "$@"

if [[ ! -s "$BENCH_JSON_OUT" ]]; then
    echo "error: benches produced no records at $BENCH_JSON_OUT" >&2
    exit 1
fi

GIT_REV="$(git -C "$ROOT" rev-parse --short HEAD 2>/dev/null || echo unknown)"
{
    printf '{"schema":"rollmux-bench-v1","git_rev":"%s","entries":[\n' "$GIT_REV"
    # Join the JSON lines with commas (each line is a complete object).
    awk 'NR>1{printf(",\n")} {printf("%s", $0)} END{printf("\n")}' "$BENCH_JSON_OUT"
    printf ']}\n'
} > "$OUT"

echo "wrote $OUT ($(grep -c '"name"' "$BENCH_JSON_OUT") entries)"
