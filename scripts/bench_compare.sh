#!/usr/bin/env bash
# Compare two BENCH_N.json perf-trajectory files (scripts/bench.sh
# output, schema rollmux-bench-v1) and FAIL when any entry shared by
# both regressed more than the threshold (default 10%, override with
# BENCH_REGRESSION_PCT).
#
# Usage: scripts/bench_compare.sh OLD.json NEW.json
#   e.g. scripts/bench_compare.sh BENCH_3.json BENCH_4.json
#
# Entries are keyed by (bench, name). Per entry the first metric both
# sides carry decides the verdict: rate metrics (ops_per_s, events_per_s,
# phases_per_s, placements_per_s) regress when they DROP; mean_s (from
# the warmup+multi-iteration harness) regresses when it RISES.
# Single-sample `wall_s` entries are deliberately NOT gated — one timed()
# run on a shared CI machine jitters well past any sane threshold — they
# are trajectory data, not gates. Placeholder files (empty entries —
# this container ships no toolchain) share nothing and pass benignly, so
# the gate arms as soon as measured files exist on both sides; compare
# like-for-like environments (same machine class for OLD and NEW).
set -euo pipefail

if [[ $# -ne 2 ]]; then
    echo "usage: $0 OLD.json NEW.json" >&2
    exit 2
fi

python3 - "$1" "$2" "${BENCH_REGRESSION_PCT:-10}" <<'PY'
import json
import sys

old_path, new_path, thresh = sys.argv[1], sys.argv[2], float(sys.argv[3])

def load(path):
    with open(path) as fh:
        doc = json.load(fh)
    return {(e.get("bench", ""), e.get("name", "")): e for e in doc.get("entries", [])}

old, new = load(old_path), load(new_path)
shared = sorted(set(old) & set(new))
if not shared:
    print(f"bench_compare: no shared entries between {old_path} and {new_path} "
          "(placeholder generation?); nothing to gate")
    sys.exit(0)

# (field, better-direction); wall_s is intentionally absent — see header.
METRICS = (
    ("ops_per_s", "high"),
    ("events_per_s", "high"),
    ("phases_per_s", "high"),
    ("placements_per_s", "high"),
    ("mean_s", "low"),
)
regressed = []
for key in shared:
    o, n = old[key], new[key]
    for field, better in METRICS:
        if field in o and field in n:
            ov, nv = float(o[field]), float(n[field])
            if ov <= 0:
                break
            delta_pct = (nv - ov) / ov * 100.0
            loss_pct = -delta_pct if better == "high" else delta_pct
            verdict = "REGRESSION" if loss_pct > thresh else "ok"
            print(f"{key[0]}/{key[1]}: {field} {ov:.6g} -> {nv:.6g} "
                  f"({delta_pct:+.1f}%) {verdict}")
            if loss_pct > thresh:
                regressed.append(key)
            break

if regressed:
    print(f"bench_compare: {len(regressed)} shared entries regressed more than "
          f"{thresh:.0f}%", file=sys.stderr)
    sys.exit(1)
print(f"bench_compare: {len(shared)} shared entries within {thresh:.0f}%")
PY
